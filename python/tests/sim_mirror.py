#!/usr/bin/env python3
"""Standalone Python mirror of the Rust discrete-event simulator.

This is a line-for-line port of the cost models and schedule builder in
``rust/src/sim/machine.rs`` and ``rust/src/strategies/mod.rs`` (Tensor3D
path) plus the engine's event-loop semantics — kept in-tree so the
empirically pinned Rust tests are diagnosable without a Rust toolchain:

* ``planner::tests::refined_choice_differs_from_volume_choice_on_gpt9b_16``
  pins that sim-refined planning picks a different grid than Eq. 4 on
  GPT-9B / 16 Polaris GPUs (replicated state).  Run this file to see the
  full candidate ranking the Rust test relies on (at authoring time:
  Eq.-4 base (2,2,4) at ~6.42 s vs sim winner (2,4,2) at ~5.86 s).
* The pipeline axis (PR 3): ``build_t3d_pipeline`` mirrors
  ``strategies::build_tensor3d_pipeline`` (1F1B schedule, Send/Recv
  rendezvous on the P2p channel-pool stream), ``bubble_fraction`` /
  ``pipelined_score`` mirror the planner's bubble-adjusted Eq.-4 term,
  and ``refine_pipelined`` mirrors the pipelined refined search of
  ``planner::PlanRequest`` (column-major placements).
  ``__main__`` asserts the pinned Rust facts: the simulated 1F1B idle
  fraction matches the analytic bubble ``(p-1)/(m+p-1)`` within 5% on a
  compute-dominated config, the refined pipelined recommendation is
  never slower than the pipeline-free Eq.-4 winner on GPT-9B/16, and the
  frontier gpt80b/1024 plan matches the CI golden.
* The placement axis (PR 4): ``placement_perm`` / ``placement_search_set``
  mirror ``spec::Placement`` (physical_ranks / search_set),
  ``place_programs`` mirrors the placed ``CommWorld`` registration
  (group member lists mapped logical->physical so ``members_per_node``
  prices the placed ranks), and ``refine_placed`` mirrors the refined
  ``planner::PlanRequest`` search over placements.  ``__main__`` asserts
  the pinned placement facts: on gpt80b/128 Polaris (replicated) the
  refined search recommends the (2, 4, 16) mesh under the ``blocked2``
  node tiling, decisively faster than the column-major default, and the
  same placement wins the paper-scale gpt80b/1024 headline mesh.
* Fast refinement (PR 5): ``reprice`` / ``simulate(..., pricing=...)``
  mirror the ``sim::PlacedWorld`` build-once/re-price-per-placement path
  (programs untouched, only per-group cost parameters move), and
  ``refine_placed`` mirrors the planner's fallback for an explicit
  placement list that admits nothing on a shortlisted mesh.  ``__main__``
  asserts the re-pricing invariant (re-priced == placed rebuild,
  bitwise, plain and pipelined) and that the refined candidate count
  equals shortlist x admissible placements.
* The fault model (PR 7): ``fault_price`` / ``simulate(..., priced=...,
  jitter=...)`` mirror the planner's degraded-world scoring run
  (``CommWorld::price_with_faults`` steady-state link pricing plus the
  splitmix64 straggler jitter of ``FaultSpec::jitter_factor``), the
  checkpoint/expected-throughput functions mirror ``comm_model``, and
  ``refine_faulted`` mirrors the fault-aware ``PlanRequest::faults``
  ranking.  ``__main__`` asserts the pinned divergence case: on
  GPT-9B/16 Polaris with G_pipe in {1,2,4} and MTBF 900 s, expected
  throughput recommends G_pipe=4 (1,1,4) — one stage per node, every
  ring intra-node — over the fault-blind G_pipe=2 (2,1,4) winner, and
  the fault-aware gpt80b/1024 plan matches the CI golden
  (ci/golden_plan_gpt80b_1024_faulted.json).
* The recovery layer (PR 10): ``recover`` mirrors
  ``planner::PlanRequest::recover_layout`` — the survivor-world
  derivation (``survivor_ranks``: dead ranks out, node eviction by
  placement), detection via a dead-rank simulation
  (``simulate(..., deaths=...)`` mirroring ``sim::detect_death``), and
  the per-policy repair-cycle pricing (``recovery_cycle_ips`` /
  ``recovery_breakeven_mttr`` mirroring ``comm_model``).  ``__main__``
  asserts the pinned crossover of
  ``planner::tests::recovery_policy_crossover_on_gpt9b_40`` — waiting
  wins at MTTR 60 s, a spare (then shrinking over waiting) wins at
  MTTR 3600 s — and authors every float in the CI recovery golden
  (ci/golden_recovery_gpt80b_1024.json).
* The issue-order permutation-invariance property of
  ``rust/tests/sim_golden.rs`` can be spot-checked here with
  ``simulate(..., order=...)``.

Python floats are IEEE-754 doubles, so where the op sequences match the
Rust engine the arithmetic matches closely; this mirror is for *ranking
and schedule-shape* diagnosis, not bit-level comparison (the Rust
``sim::reference`` engine is the bitwise golden).

No dependencies beyond the standard library.  Usage::

    python3 python/tests/sim_mirror.py            # refine scan, pinned cases
"""
import heapq
import json
import math
import os

BYTES_PER_ELEM = 2.0
COMPUTE, AR, AG, RS, SEND, RECV = 0, 1, 2, 3, 4, 5
STATE_BUDGET = 0.6


class Machine:
    def __init__(self, name, gpn, peak, mem, intra_bw, intra_lat, inter_bw, nic, inter_lat,
                 effmax, halfdim, tiers=None, flat_collectives=False):
        self.name = name
        self.gpus_per_node = gpn
        self.peak_flops = peak
        self.mem_bytes = mem
        self.intra_bw = intra_bw
        self.intra_lat_s = intra_lat
        self.inter_bw_per_node = inter_bw
        self.nic_bw = nic
        self.inter_lat_s = inter_lat
        self.gemm_eff_max = effmax
        self.gemm_eff_halfdim = halfdim
        # mirror of sim::fabric::Tier: [(name, radix, bw, link_bw, lat_s)]
        # from the node tier up; [] = flat two-level machine
        self.tiers = list(tiers) if tiers else []
        self.flat_collectives = flat_collectives

    def gemm_eff(self, md):
        return self.gemm_eff_max * md / (md + self.gemm_eff_halfdim)

    def compute_time(self, flops, md):
        if flops <= 0:
            return 0.0
        return flops / (self.peak_flops * max(self.gemm_eff(md), 1e-3))

    def ring_bw_lat(self, p, per_node):
        if per_node >= p:
            return (self.intra_bw, self.intra_lat_s)
        cg = max(self.gpus_per_node // max(per_node, 1), 1)
        share = min(self.inter_bw_per_node / cg, self.nic_bw)
        return (min(share, self.intra_bw), self.inter_lat_s)

    def allreduce_time(self, bytes_, p, per_node):
        if p <= 1 or bytes_ <= 0:
            return 0.0
        pf = float(p)
        rb = 2.0 * (pf - 1.0) / pf * bytes_
        bw, lat = self.ring_bw_lat(p, per_node)
        return rb / bw + 2.0 * (pf - 1.0) * lat

    def allgather_time(self, bytes_, p, per_node):
        if p <= 1 or bytes_ <= 0:
            return 0.0
        pf = float(p)
        rb = (pf - 1.0) / pf * bytes_
        bw, lat = self.ring_bw_lat(p, per_node)
        return rb / bw + (pf - 1.0) * lat

    def reduce_scatter_time(self, b, p, pn):
        return self.allgather_time(b, p, pn)

    def p2p_time(self, bytes_, per_node):
        if bytes_ <= 0:
            return 0.0
        bw, lat = self.ring_bw_lat(2, per_node)
        return bytes_ / bw + lat

    def members_per_node(self, group):
        per = {}
        for r in group:
            per[r // self.gpus_per_node] = per.get(r // self.gpus_per_node, 0) + 1
        return max(per.values()) if per else 1


def perlmutter():
    return Machine("perlmutter", 4, 312e12, 40e9, 200e9, 2e-6, 100e9, 25e9, 4e-6, 0.62, 96.0)


def polaris():
    return Machine("polaris", 4, 312e12, 40e9, 200e9, 2e-6, 25e9, 12.5e9, 4e-6, 0.62, 96.0)


def frontier():
    return Machine("frontier", 8, 191.5e12, 64e9, 100e9, 2e-6, 100e9, 25e9, 4e-6, 0.55, 96.0)


def perlmutter_xl():
    """Mirror of Machine::perlmutter_xl (8 GPUs/node x 64-node rails x
    128 rails = 65,536 GPUs; rail-optimized fat tree, 4:1 oversubscribed
    into the spine)."""
    tiers = [("node", 8, 300e9, 300e9, 2e-6),
             ("rail", 64, 4.0 * 25e9, 25e9, 4e-6),
             ("spine", 128, 1.6e12, 12.5e9, 6e-6)]
    return Machine("perlmutter-xl", 8, 312e12, 80e9, 300e9, 2e-6, 4.0 * 25e9, 25e9, 4e-6,
                   0.62, 96.0, tiers=tiers)


FLAT_TOP_RADIX = 1 << 24


def flat_tiers(machine):
    """Mirror of fabric::flat_tiers: the two-tier embedding of a flat
    Machine (node tier from the intra parameters, one boundless fabric
    tier from the NIC parameters)."""
    return [("node", machine.gpus_per_node, machine.intra_bw, machine.intra_bw,
             machine.intra_lat_s),
            ("fabric", FLAT_TOP_RADIX, machine.inter_bw_per_node, machine.nic_bw,
             machine.inter_lat_s)]


def unit_sizes(tiers):
    """Mirror of fabric::unit_sizes: cumulative radix products."""
    out, acc = [], 1
    for (_, radix, _, _, _) in tiers:
        acc *= radix
        out.append(acc)
    return out


def max_per_unit(members, unit):
    """Mirror of fabric::max_per_unit: most members sharing one
    ``unit``-sized block of ranks."""
    best = 1
    for i, r in enumerate(members):
        u = r // unit
        if any(q // unit == u for q in members[:i]):
            continue
        best = max(best, sum(1 for q in members[i:] if q // unit == u))
    return best


def tiered_bw_lat(machine, members):
    """Mirror of fabric::tiered_bw_lat: price a ring over ``members`` at
    the highest tier it spans, splitting that tier's bandwidth across
    the same-shape groups sharing its links and capping at every lower
    tier's per-link ceiling."""
    tiers = machine.tiers if machine.tiers else flat_tiers(machine)
    sizes = unit_sizes(tiers)
    t = 0
    for k in range(len(tiers)):
        t = k
        if all(r // sizes[k] == members[0] // sizes[k] for r in members):
            break
    if t == 0:
        return (tiers[0][2], tiers[0][4])
    per_unit = max_per_unit(members, sizes[t - 1])
    cg = max(sizes[t - 1] // max(per_unit, 1), 1)
    share = min(tiers[t][2] / cg, tiers[t][3])
    for k in range(1, t):
        share = min(share, tiers[k][3])
    return (min(share, tiers[0][2]), tiers[t][4])


class Mesh:
    def __init__(self, gd, gr, gc, depth=1):
        self.g_data, self.g_r, self.g_c, self.depth = gd, gr, gc, depth

    def g_tensor(self):
        return self.g_r * self.g_c

    def world(self):
        return self.g_data * self.g_tensor()

    def coord_of(self, rank):
        t = self.g_tensor()
        return (rank // t, (rank % t) // self.g_r, rank % self.g_r)  # (d, j, i)

    def rank_of(self, d, i, j):
        return d * self.g_tensor() + j * self.g_r + i

    def col_group(self, rank):
        d, j, _ = self.coord_of(rank)
        return tuple(self.rank_of(d, ii, j) for ii in range(self.g_r))

    def row_group(self, rank):
        d, _, i = self.coord_of(rank)
        return tuple(self.rank_of(d, i, jj) for jj in range(self.g_c))

    def data_group(self, rank):
        _, j, i = self.coord_of(rank)
        return tuple(self.rank_of(dd, i, j) for dd in range(self.g_data))

    def key(self):
        return (self.g_data, self.g_r, self.g_c)


def divisors(n):
    return [d for d in range(1, n + 1) if n % d == 0]


def factorizations(world):
    out = []
    for gd in divisors(world):
        t = world // gd
        for gr in divisors(t):
            out.append(Mesh(gd, gr, t // gr))
    return out


class Layer:
    def __init__(self, name, k, n, rows, transposed):
        self.name, self.k, self.n, self.rows, self.transposed = name, k, n, rows, transposed

    def fwd_flops(self, samples):
        return 2.0 * samples * self.rows * self.k * self.n

    def weight_params(self):
        return float(self.k * self.n)


class Net:
    def __init__(self, layers, attached, params):
        self.layers, self.attached, self.params = layers, attached, params

    def fc_params(self):
        return sum(l.weight_params() for l in self.layers)


def gpt_network(vocab, hidden, layers, heads, seq):
    """Mirror of models::gpt::GptDims::network()."""
    h = hidden
    L, A = [], []
    for l in range(layers):
        L.append(Layer(f"b{l}.qkv", h, 3 * h, seq, False))
        A.append((len(L) - 1, 4.0 * seq * seq * h))
        L.append(Layer(f"b{l}.proj", h, h, seq, True))
        L.append(Layer(f"b{l}.mlp1", h, 4 * h, seq, False))
        L.append(Layer(f"b{l}.mlp2", 4 * h, h, seq, True))
    L.append(Layer("head", h, vocab, seq, False))
    f, v, s = 4 * h, vocab, seq
    per_block = h * 3.0 * h + 3.0 * h + h * h + h + h * f + f + f * h + h + 4.0 * h
    params = v * h + s * h + layers * per_block + 2.0 * h + h * v + v
    return Net(L, A, params)


def ar_vol(p, buf):
    return 0.0 if p <= 1 else 2.0 * (p - 1.0) / p * buf


def t3d_volume(net, batch, mesh):
    """Mirror of comm_model::tensor3d_network_volume (elements/GPU/iter)."""
    tot = 0.0
    for l in net.layers:
        m = batch / mesh.g_data * l.rows
        gr, gc = (mesh.g_c, mesh.g_r) if l.transposed else (mesh.g_r, mesh.g_c)
        tot += ar_vol(gr, m * l.n / gc) + ar_vol(gc, m * l.k / gr)
    return tot


def state_bytes(net, gt):
    return 16.0 * net.params / gt


def state_bytes_sharded(net, gt, gd):
    return (4.0 + 12.0 / gd) * net.params / gt


def min_g_tensor(net, machine, world):
    for gt in divisors(world):
        if state_bytes(net, gt) <= machine.mem_bytes * STATE_BUDGET:
            return gt
    return world


def candidates(net, batch, world, machine, mode):
    """Feasible meshes sorted by Eq.-4 volume (mode: 'rep' | 'sh')."""
    if mode == "rep":
        floor = min_g_tensor(net, machine, world)
        ms = [m for m in factorizations(world) if m.g_tensor() >= floor]
    else:
        budget = machine.mem_bytes * STATE_BUDGET
        ms = [m for m in factorizations(world)
              if state_bytes_sharded(net, m.g_tensor(), m.g_data) <= budget]
    out = [(m, t3d_volume(net, batch, m)) for m in ms]
    out.sort(key=lambda x: x[1])
    return out


def base_plan(cands):
    """Rule 1 (max g_data) + rule 2 (min volume) — the volume stage of
    planner::PlanRequest::run."""
    gdmax = max(m.g_data for m, _ in cands)
    return min(((m, v) for m, v in cands if m.g_data == gdmax), key=lambda x: x[1])


def build_t3d(net, mesh_in, batch, depth, machine, sharded=False, barrier=False):
    """Mirror of strategies::build_tensor3d (transpose_opt = true).

    Per-rank op tuples: (kind, a, b, tag, group, stream, deps) where for
    COMPUTE a=flops b=min_dim, for collectives a=bytes.
    """
    del machine  # groups are resolved at simulate time in the mirror
    mesh = Mesh(mesh_in.g_data, mesh_in.g_r, mesh_in.g_c, depth)
    world = mesh.world()
    spe = batch / (mesh.g_data * depth)
    use_shard = sharded and mesh.g_data > 1
    gt = mesh.g_tensor()
    GK_COL, GK_ROW, GK_DATA = 0, 1, 2
    PH_FWD, PH_BWD, PH_DP, PH_WG, PH_GS = 1, 2, 4, 5, 6

    def tag(phase, layer, shard, gk, gid):
        return (phase << 58) | (layer << 38) | (shard << 30) | (gk << 27) | gid

    programs = []
    for rank in range(world):
        d, j, i = mesh.coord_of(rank)
        ops = []

        def push(kind, a, b, tg, grp, stream, deps):
            ops.append((kind, a, b, tg, grp, stream, tuple(deps)))
            return len(ops) - 1

        dp_gid = i * mesh.g_c + j
        col, row, datag = mesh.col_group(rank), mesh.row_group(rank), mesh.data_group(rank)
        last_fwd = [None] * depth
        for li, layer in enumerate(net.layers):
            wg = None
            if use_shard:
                byts = layer.weight_params() / gt * BYTES_PER_ELEM
                deps = []
                if barrier:
                    deps = [x for x in last_fwd if x is not None]
                wg = push(AG, byts, 0, tag(PH_WG, li, 0, GK_DATA, dp_gid), datag, 2, deps)
            if layer.transposed:
                gre, gce, fwd_gk, fwd_gid, fwd_group = mesh.g_c, mesh.g_r, GK_ROW, d * mesh.g_r + i, row
            else:
                gre, gce, fwd_gk, fwd_gid, fwd_group = mesh.g_r, mesh.g_c, GK_COL, d * mesh.g_c + j, col
            m_local = spe * layer.rows
            flops = layer.fwd_flops(spe) / gt
            md = min(m_local, layer.k / gre, layer.n / gce)
            ar_bytes = m_local * layer.n / gce * BYTES_PER_ELEM
            for s in range(depth):
                deps = []
                if last_fwd[s] is not None:
                    deps.append(last_fwd[s])
                if wg is not None:
                    deps.append(wg)
                mm = push(COMPUTE, flops, md, 0, None, 0, deps)
                ar = push(AR, ar_bytes, 0, tag(PH_FWD, li, s, fwd_gk, fwd_gid), fwd_group, 1, [mm])
                tail = ar
                for (al, af) in net.attached:
                    if al == li:
                        tail = push(COMPUTE, af * spe / mesh.g_c, m_local, 0, None, 0, [tail])
                last_fwd[s] = tail
        last_bwd = list(last_fwd)
        last_dw = [None] * depth
        gscatters, last_rs = [], None
        for li in range(len(net.layers) - 1, -1, -1):
            layer = net.layers[li]
            if layer.transposed:
                gre, gce, bwd_gk, bwd_gid, bwd_group = mesh.g_c, mesh.g_r, GK_COL, d * mesh.g_c + j, col
            else:
                gre, gce, bwd_gk, bwd_gid, bwd_group = mesh.g_r, mesh.g_c, GK_ROW, d * mesh.g_r + i, row
            m_local = spe * layer.rows
            flops = layer.fwd_flops(spe) / gt
            md = min(m_local, layer.k / gre, layer.n / gce)
            ar_bytes = m_local * layer.k / gre * BYTES_PER_ELEM
            for s in range(depth):
                deps = []
                if last_bwd[s] is not None:
                    deps.append(last_bwd[s])
                if barrier and last_rs is not None:
                    deps.append(last_rs)
                rc = push(COMPUTE, flops, md, 0, None, 0, deps)
                deps = [rc]
                for (al, af) in net.attached:
                    if al == li:
                        ab = push(COMPUTE, 3.0 * af * spe / mesh.g_c, m_local, 0, None, 0, deps)
                        deps = [ab]
                dx = push(COMPUTE, flops, md, 0, None, 0, deps)
                ar = push(AR, ar_bytes, 0, tag(PH_BWD, li, s, bwd_gk, bwd_gid), bwd_group, 1, [dx])
                dw = push(COMPUTE, flops, md, 0, None, 0, deps)
                last_bwd[s], last_dw[s] = ar, dw
            if use_shard:
                byts = layer.weight_params() / gt * BYTES_PER_ELEM
                deps = [x for x in last_dw if x is not None]
                rs = push(RS, byts, 0, tag(PH_GS, li, 0, GK_DATA, dp_gid), datag, 2, deps)
                gscatters.append(rs)
                last_rs = rs
        if use_shard:
            push(COMPUTE, 12.0 * net.fc_params() / (gt * mesh.g_data), 1e9, 0, None, 0,
                 list(gscatters))
        if mesh.g_data > 1 and not use_shard:
            gb = net.fc_params() / gt * BYTES_PER_ELEM
            deps = []
            for s in range(depth):
                if last_dw[s] is not None:
                    deps.append(last_dw[s])
                if last_bwd[s] is not None:
                    deps.append(last_bwd[s])
            dp = push(AR, gb, 0, tag(PH_DP, 0, 0, GK_DATA, i * mesh.g_c + j), datag, 1, deps)
            push(COMPUTE, 12.0 * net.fc_params() / gt, 1e9, 0, None, 0, [dp])
        programs.append(ops)
    return programs


def hierarchize(machine, programs):
    """Mirror of the ProgramSetBuilder's hierarchical decomposition on
    tiered machines: every AR/AG/RS over a group with >= 2 members on
    each of >= 2 nodes (uniformly) expands into intra-node + rail
    sub-ops with chained deps on the caller's stream; everything else —
    and every program on a flat or ``flat_collectives`` machine — is
    returned untouched.  Sub-op rendezvous tags are ``(base_tag, phase,
    subgroup)`` tuples, disjoint from the integer tags of flat ops."""
    if not machine.tiers or machine.flat_collectives:
        return programs
    gpn = machine.gpus_per_node
    split_cache = {}

    def split(grp):
        if grp in split_cache:
            return split_cache[grp]
        by_node, slot = [], {}
        for r in grp:
            s = slot.setdefault(r // gpn, len(by_node))
            if s == len(by_node):
                by_node.append([])
            by_node[s].append(r)
        m = len(by_node[0])
        if len(by_node) < 2 or m < 2 or any(len(v) != m for v in by_node):
            split_cache[grp] = None
        else:
            per = {}
            for j in range(m):
                rail = tuple(v[j] for v in by_node)
                for v in by_node:
                    per[v[j]] = (tuple(v), rail)
            split_cache[grp] = (m, per)
        return split_cache[grp]

    out = []
    for rank, ops in enumerate(programs):
        new, remap = [], {}
        for oi, (kind, a, b, tg, grp, stream, deps) in enumerate(ops):
            deps = tuple(remap[d] for d in deps)
            sp = split(grp) if kind in (AR, AG, RS) and grp is not None else None
            if sp is None:
                new.append((kind, a, b, tg, grp, stream, deps))
            else:
                m, per = sp
                intra, rail = per[rank]
                if kind == AR:
                    new.append((RS, a, b, (tg, 0, intra), intra, stream, deps))
                    new.append((AR, a / m, b, (tg, 1, rail), rail, stream, (len(new) - 1,)))
                    new.append((AG, a, b, (tg, 2, intra), intra, stream, (len(new) - 1,)))
                elif kind == AG:
                    new.append((AG, a / m, b, (tg, 1, rail), rail, stream, deps))
                    new.append((AG, a, b, (tg, 2, intra), intra, stream, (len(new) - 1,)))
                else:
                    new.append((RS, a, b, (tg, 0, intra), intra, stream, deps))
                    new.append((RS, a / m, b, (tg, 1, rail), rail, stream, (len(new) - 1,)))
            remap[oi] = len(new) - 1
        out.append(new)
    return out


def coll_time_on(kind, bytes_, p, bw, lat):
    """Mirror of OpKind::collective_time_on (the explicitly-priced
    engine path): ring all-reduce / all-gather / reduce-scatter and the
    single-hop P2p transfer on a given (bw, lat)."""
    if kind in (SEND, RECV):
        return 0.0 if bytes_ <= 0 else bytes_ / bw + lat
    if p <= 1 or bytes_ <= 0:
        return 0.0
    if kind == AR:
        return 2.0 * (p - 1.0) / p * bytes_ / bw + 2.0 * (p - 1.0) * lat
    return (p - 1.0) / p * bytes_ / bw + (p - 1.0) * lat


def simulate(machine, programs, order=None, pricing=None, priced=None, jitter=None,
             deaths=None):
    """Mirror of sim::engine::simulate / simulate_permuted.

    Returns ``(makespan, compute_busy)``.  Stream 3 (P2p) mirrors the
    engine's channel-pool semantics: an in-flight Send/Recv transfer
    never updates ``stream_free``, so the next P2p op's start is governed
    solely by deps and partner readiness.

    ``pricing`` mirrors the re-priced ``sim::PlacedWorld`` path: a map
    from each logical group tuple to the per-node occupancy of its
    *placed* members (see ``reprice``), overriding the occupancy that
    would be derived from the logical ranks — programs stay untouched,
    only the communicator cost parameters move.

    ``priced`` (PR 7) is the stronger override the fault path needs: a
    map from each logical group tuple straight to ``(bw, lat)`` — the
    mirror of ``CommWorld::price_with_faults`` feeding
    ``sim::simulate_repriced_faulted`` (degraded links are a bandwidth
    *scale*, not expressible as an occupancy).  ``jitter`` is the
    per-rank compute-duration multiplier list of
    ``FaultSpec::jitter_factor`` (see ``jitter_factors``).

    ``deaths`` (PR 10) mirrors ``FaultCtx::death``: a per-rank death
    time list (``inf`` = alive) — a dead rank issues no op whose start
    is at or past its death, so the run quiesces at the first
    collective that needs it.  In this mode the return is a 3-tuple
    ``(time, compute_busy, stuck_ops)``: ``stuck_ops == 0`` means the
    program outran the death and ``time`` is the plain makespan;
    otherwise ``time`` is the detection (quiesce) time of
    ``StallError::at_s`` — the last completed event.
    """
    n = len(programs)
    done = [[False] * len(p) for p in programs]
    done_time = [[0.0] * len(p) for p in programs]
    nxt = [[0, 0, 0, 0] for _ in range(n)]
    stream_ops = []
    for p in programs:
        m = [[], [], [], []]
        for idx, op in enumerate(p):
            m[op[5]].append(idx)
        stream_ops.append(m)
    stream_free = [[0.0, 0.0, 0.0, 0.0] for _ in range(n)]
    compute_busy = [0.0] * n
    collectives = {}
    heap = []
    state = {"seq": 0, "now": 0.0}
    pernode_cache = {}
    tiered_cache = {}

    def per_node(grp):
        if pricing is not None:
            return pricing[grp]
        r = pernode_cache.get(grp)
        if r is None:
            r = machine.members_per_node(grp)
            pernode_cache[grp] = r
        return r

    def tiered(grp):
        # mirror of Machine::group_bw_lat on tiered machines (the
        # ``pricing`` occupancy override is a flat-ring concept; placed
        # tiered runs feed ``priced`` maps instead)
        r = tiered_cache.get(grp)
        if r is None:
            r = tiered_bw_lat(machine, grp)
            tiered_cache[grp] = r
        return r

    def try_issue(gpu):
        progressed = True
        while progressed:
            progressed = False
            for st in range(4):
                ip, sl = nxt[gpu][st], stream_ops[gpu][st]
                if ip >= len(sl):
                    continue
                oi = sl[ip]
                op = programs[gpu][oi]
                ready = max(stream_free[gpu][st], state["now"])
                ok = True
                for dd in op[6]:
                    if not done[gpu][dd]:
                        ok = False
                        break
                    ready = max(ready, done_time[gpu][dd])
                if not ok:
                    continue
                if deaths is not None and ready >= deaths[gpu]:
                    # a dead rank issues nothing starting at or past its
                    # death: its streams block and the first collective
                    # needing it becomes the detected stall
                    continue
                kind = op[0]
                if kind == COMPUTE:
                    dur = machine.compute_time(op[1], op[2])
                    if jitter is not None:
                        dur *= jitter[gpu]
                    end = ready + dur
                    nxt[gpu][st] += 1
                    stream_free[gpu][st] = end
                    compute_busy[gpu] += dur
                    state["seq"] += 1
                    heapq.heappush(heap, (end, state["seq"], gpu, oi))
                    progressed = True
                else:
                    tg, grp = op[3], op[4]
                    stt = collectives.get(tg)
                    if stt is None:
                        stt = [0, len(grp), 0.0, []]
                        collectives[tg] = stt
                    stt[0] += 1
                    stt[2] = max(stt[2], ready)
                    stt[3].append((gpu, oi))
                    nxt[gpu][st] += 1
                    if stt[0] == stt[1]:
                        p = len(grp)
                        if priced is not None:
                            bw, lat = priced[grp]
                            dur = coll_time_on(kind, op[1], p, bw, lat)
                        elif machine.tiers and pricing is None:
                            bw, lat = tiered(grp)
                            dur = coll_time_on(kind, op[1], p, bw, lat)
                        elif kind == AR:
                            dur = machine.allreduce_time(op[1], p, per_node(grp))
                        elif kind == AG:
                            dur = machine.allgather_time(op[1], p, per_node(grp))
                        elif kind in (SEND, RECV):
                            dur = machine.p2p_time(op[1], per_node(grp))
                        else:
                            dur = machine.reduce_scatter_time(op[1], p, per_node(grp))
                        end = stt[2] + dur
                        for (mg, mi) in stt[3]:
                            # P2p (stream 3) is a channel pool: completion
                            # never serializes the stream
                            if programs[mg][mi][5] != 3:
                                stream_free[mg][programs[mg][mi][5]] = end
                            state["seq"] += 1
                            heapq.heappush(heap, (end, state["seq"], mg, mi))
                        del collectives[tg]
                    progressed = True

    wl = list(order) if order is not None else list(range(n))
    while wl:
        try_issue(wl.pop())
    while heap:
        t, _, g, i = heapq.heappop(heap)
        state["now"] = t
        done[g][i] = True
        done_time[g][i] = t
        try_issue(g)
    if deaths is not None:
        stuck = sum(1 for g in range(n) for d in done[g] if not d)
        if stuck:
            return state["now"], compute_busy, stuck
        return max(max(v) if v else 0.0 for v in done_time), compute_busy, 0
    for g in range(n):
        assert all(done[g]), f"deadlock on gpu {g}"
    return max(max(v) if v else 0.0 for v in done_time), compute_busy


def pipeline_steps(stage, stages, m):
    """Mirror of pipeline::steps (OneFOneB): [('F'|'B', microbatch), ...]."""
    warmup = min(stages - 1 - stage, m)
    out = [("F", i) for i in range(warmup)]
    for k in range(m - warmup):
        out.append(("F", warmup + k))
        out.append(("B", k))
    out.extend(("B", k) for k in range(m - warmup, m))
    return out


def partition_layers(costs, stages):
    """Mirror of pipeline::partition_layers: list of (start, end) ranges."""
    n = len(costs)
    assert 1 <= stages <= n
    cum = [0.0]
    for c in costs:
        cum.append(cum[-1] + c)
    total = cum[n]
    cuts = [0]
    for s in range(1, stages):
        target = total * s / stages
        cut = next(i for i in range(n + 1) if cum[i] >= target)
        cuts.append(max(cuts[s - 1] + 1, min(cut, n - (stages - s))))
    cuts.append(n)
    return [(cuts[i], cuts[i + 1]) for i in range(stages)]


def ptag(phase, mb, layer, shard, gk, gid):
    """Mirror of strategies::ptag (pipelined tag packing)."""
    return (phase << 58) | (mb << 44) | (layer << 30) | (shard << 24) | (gk << 21) | gid


def build_t3d_pipeline(net, mesh_in, batch, depth, stages, microbatches, machine,
                       sharded=False):
    """Mirror of strategies::build_tensor3d_pipeline (transpose_opt on)."""
    del machine
    assert stages >= 2
    mesh = Mesh(mesh_in.g_data, mesh_in.g_r, mesh_in.g_c, depth)
    inner = mesh.world()
    world = stages * inner
    gt = mesh.g_tensor()
    spe = batch / (mesh.g_data * microbatches * depth)
    use_shard = sharded and mesh.g_data > 1
    GK_COL, GK_ROW, GK_DATA, GK_P2P = 0, 1, 2, 3
    PH_FWD, PH_BWD, PH_DP, PH_WG, PH_GS, PH_PF, PH_PB = 1, 2, 4, 5, 6, 7, 8
    costs = []
    for li, l in enumerate(net.layers):
        att = sum(af for (al, af) in net.attached if al == li)
        costs.append(l.fwd_flops(1.0) + att)
    ranges = partition_layers(costs, stages)

    programs = []
    for rank in range(world):
        stage, inner_rank = rank // inner, rank % inner
        d, j, i = mesh.coord_of(inner_rank)
        lo, hi = ranges[stage]
        stage_params = sum(net.layers[li].weight_params() for li in range(lo, hi))
        ops = []

        def push(kind, a, bb, tg, grp, stream, deps):
            ops.append((kind, a, bb, tg, grp, stream, tuple(deps)))
            return len(ops) - 1

        def lift(grp):
            return tuple(r + stage * inner for r in grp)

        dp_gid = i * mesh.g_c + j
        col = lift(mesh.col_group(inner_rank))
        row = lift(mesh.row_group(inner_rank))
        datag = lift(mesh.data_group(inner_rank))
        prev_g = (rank - inner, rank) if stage > 0 else None
        next_g = (rank, rank + inner) if stage + 1 < stages else None

        def boundary_bytes(bl):
            l = net.layers[bl]
            gce = mesh.g_r if l.transposed else mesh.g_c
            return spe * l.rows * l.n / gce * BYTES_PER_ELEM

        fwd_in_bytes = boundary_bytes(lo - 1) if stage > 0 else None
        fwd_out_bytes = boundary_bytes(hi - 1) if stage + 1 < stages else None

        wgather = [None] * len(net.layers)
        if use_shard:
            for li in range(lo, hi):
                byts = net.layers[li].weight_params() / gt * BYTES_PER_ELEM
                wgather[li] = push(AG, byts, 0, ptag(PH_WG, 0, li, 0, GK_DATA, dp_gid),
                                   datag, 2, [])

        fwd_tail = [[None] * depth for _ in range(microbatches)]
        final_dw = [[] for _ in range(len(net.layers))]
        last_dw = [None] * depth
        last_bwd = [None] * depth

        for (what, mb) in pipeline_steps(stage, stages, microbatches):
            if what == "F":
                cur = [None] * depth
                if prev_g is not None:
                    for s in range(depth):
                        cur[s] = push(RECV, fwd_in_bytes, 0,
                                      ptag(PH_PF, mb, stage, s, GK_P2P, inner_rank),
                                      prev_g, 3, [])
                for li in range(lo, hi):
                    layer = net.layers[li]
                    if layer.transposed:
                        gre, gce, fgk, fgid, fgrp = mesh.g_c, mesh.g_r, GK_ROW, d * mesh.g_r + i, row
                    else:
                        gre, gce, fgk, fgid, fgrp = mesh.g_r, mesh.g_c, GK_COL, d * mesh.g_c + j, col
                    m_local = spe * layer.rows
                    flops = layer.fwd_flops(spe) / gt
                    md = min(m_local, layer.k / gre, layer.n / gce)
                    ar_bytes = m_local * layer.n / gce * BYTES_PER_ELEM
                    for s in range(depth):
                        deps = []
                        if cur[s] is not None:
                            deps.append(cur[s])
                        if wgather[li] is not None:
                            deps.append(wgather[li])
                        mm = push(COMPUTE, flops, md, 0, None, 0, deps)
                        tail = push(AR, ar_bytes, 0, ptag(PH_FWD, mb, li, s, fgk, fgid),
                                    fgrp, 1, [mm])
                        for (al, af) in net.attached:
                            if al == li:
                                tail = push(COMPUTE, af * spe / mesh.g_c, m_local, 0, None,
                                            0, [tail])
                        cur[s] = tail
                if next_g is not None:
                    for s in range(depth):
                        push(SEND, fwd_out_bytes, 0,
                             ptag(PH_PF, mb, stage + 1, s, GK_P2P, inner_rank),
                             next_g, 3, [cur[s]])
                fwd_tail[mb] = cur
            else:
                rx = [None] * depth
                if next_g is not None:
                    for s in range(depth):
                        rx[s] = push(RECV, fwd_out_bytes, 0,
                                     ptag(PH_PB, mb, stage + 1, s, GK_P2P, inner_rank),
                                     next_g, 3, [])
                cur = [None] * depth
                for li in range(hi - 1, lo - 1, -1):
                    layer = net.layers[li]
                    if layer.transposed:
                        gre, gce, bgk, bgid, bgrp = mesh.g_c, mesh.g_r, GK_COL, d * mesh.g_c + j, col
                    else:
                        gre, gce, bgk, bgid, bgrp = mesh.g_r, mesh.g_c, GK_ROW, d * mesh.g_r + i, row
                    m_local = spe * layer.rows
                    flops = layer.fwd_flops(spe) / gt
                    md = min(m_local, layer.k / gre, layer.n / gce)
                    ar_bytes = m_local * layer.k / gre * BYTES_PER_ELEM
                    for s in range(depth):
                        deps = []
                        if cur[s] is not None:
                            deps.append(cur[s])
                        else:
                            if fwd_tail[mb][s] is not None:
                                deps.append(fwd_tail[mb][s])
                            if rx[s] is not None:
                                deps.append(rx[s])
                        rc = push(COMPUTE, flops, md, 0, None, 0, deps)
                        deps = [rc]
                        for (al, af) in net.attached:
                            if al == li:
                                ab = push(COMPUTE, 3.0 * af * spe / mesh.g_c, m_local, 0,
                                          None, 0, deps)
                                deps = [ab]
                        dx = push(COMPUTE, flops, md, 0, None, 0, deps)
                        ar = push(AR, ar_bytes, 0, ptag(PH_BWD, mb, li, s, bgk, bgid),
                                  bgrp, 1, [dx])
                        dw = push(COMPUTE, flops, md, 0, None, 0, deps)
                        cur[s] = ar
                        last_bwd[s] = ar
                        last_dw[s] = dw
                        if mb == microbatches - 1:
                            final_dw[li].append(dw)
                if prev_g is not None:
                    for s in range(depth):
                        push(SEND, fwd_in_bytes, 0,
                             ptag(PH_PB, mb, stage, s, GK_P2P, inner_rank),
                             prev_g, 3, [cur[s]])

        if use_shard:
            gscatters = []
            for li in range(hi - 1, lo - 1, -1):
                byts = net.layers[li].weight_params() / gt * BYTES_PER_ELEM
                rs = push(RS, byts, 0, ptag(PH_GS, 0, li, 0, GK_DATA, dp_gid), datag, 2,
                          final_dw[li])
                gscatters.append(rs)
            push(COMPUTE, 12.0 * stage_params / (gt * mesh.g_data), 1e9, 0, None, 0,
                 gscatters)
        if mesh.g_data > 1 and not use_shard:
            gb = stage_params / gt * BYTES_PER_ELEM
            deps = []
            for s in range(depth):
                if last_dw[s] is not None:
                    deps.append(last_dw[s])
                if last_bwd[s] is not None:
                    deps.append(last_bwd[s])
            dp = push(AR, gb, 0, ptag(PH_DP, 0, lo, 0, GK_DATA, dp_gid), datag, 1, deps)
            push(COMPUTE, 12.0 * stage_params / gt, 1e9, 0, None, 0, [dp])
        programs.append(ops)
    return programs


def bubble_fraction(p, m):
    """Mirror of comm_model::pipeline_bubble_fraction: (p-1)/(m+p-1)."""
    return 0.0 if p <= 1 else (p - 1) / (m + p - 1)


def pipelined_score(net, batch, mesh, p, m):
    """Mirror of comm_model::pipelined_volume_score."""
    return t3d_volume(net, batch, mesh) / p / (1.0 - bubble_fraction(p, m))


def pipelined_candidates(net, batch, world, machine, mode, pipes, m, k):
    """Mirror of the refined planner::PlanRequest's per-G_pipe
    shortlists: the k best by bubble-adjusted score, rule-blind (the
    §5 g_data rule only picks the volume-stage winner — re-ranking
    exists because that rule ignores NIC sharing and latency)."""
    budget = machine.mem_bytes * STATE_BUDGET
    out = []
    for p in pipes:
        if p == 0 or world % p or len(net.layers) < p:
            continue
        feas = []
        for mm in factorizations(world // p):
            st = (state_bytes(net, mm.g_tensor()) if mode == "rep"
                  else state_bytes_sharded(net, mm.g_tensor(), mm.g_data))
            if st / p <= budget:
                feas.append((mm, pipelined_score(net, batch, mm, p, m)))
        feas.sort(key=lambda x: x[1])
        out.extend((p, mm, v) for mm, v in feas[:max(k, 1)])
    out.sort(key=lambda x: x[2])
    return out


def refine(net, batch, world, machine, mode, k=6, depth=2):
    """Mirror of the refined planner::PlanRequest at G_pipe = 1 with
    column-major placement (Tensor3D, transpose_opt on): the shortlist
    is the rule-blind top-k by volume, plus the §5 base anchor."""
    cands = candidates(net, batch, world, machine, mode)
    base, _ = base_plan(cands)
    top = [m for m, _ in cands[:max(k, 1)]]
    if base.key() not in [m.key() for m in top]:
        top.append(base)
    scored = []
    for m in top:
        progs = build_t3d(net, m, batch, depth, machine, sharded=(mode == "sh"))
        progs = hierarchize(machine, progs)  # identity on flat machines
        scored.append((m, simulate(machine, progs)[0]))
    scored.sort(key=lambda x: x[1])
    basemk = [mk for m, mk in scored if m.key() == base.key()][0]
    return base, basemk, scored


def placement_perm(label, g_pipe, gd, gr, gc, gpn):
    """Mirror of spec::Placement::physical_ranks (label form)."""
    gt = gr * gc
    inner = gd * gt
    world = g_pipe * inner
    out = [0] * world
    for rank in range(world):
        stage, ir = rank // inner, rank % inner
        d, t = ir // gt, ir % gt
        j, i = t // gr, t % gr
        if label == "column-major":
            phys = rank
        elif label == "row-major":
            phys = stage * inner + d * gt + i * gc + j
        elif label == "depth-outer":
            phys = (d * g_pipe + stage) * gt + j * gr + i
        elif label.startswith("blocked"):
            rows = int(label[len("blocked"):])
            cols = gpn // rows
            assert gpn % rows == 0 and gr % rows == 0 and gc % cols == 0
            bi, ii = i // rows, i % rows
            bj, jj = j // cols, j % cols
            g = (bj * (gr // rows) + bi) * (rows * cols) + jj * rows + ii
            phys = stage * inner + d * gt + g
        else:
            raise ValueError(label)
        out[rank] = phys
    assert sorted(out) == list(range(world))
    return out


def placement_admissible(label, g_pipe, gd, gr, gc, gpn):
    """Mirror of spec::Placement::admissible (label form)."""
    del g_pipe, gd
    if label.startswith("blocked"):
        rows = int(label[len("blocked"):])
        return rows >= 1 and gpn % rows == 0 and gr % rows == 0 and gc % (gpn // rows) == 0
    return True


def placement_search_set(g_pipe, gd, gr, gc, gpn):
    """Mirror of spec::Placement::search_set (column-major first, named
    variants deduped by permutation)."""
    world = g_pipe * gd * gr * gc
    out, seen = ["column-major"], [list(range(world))]
    cands = ["row-major", "depth-outer"] + [f"blocked{r}" for r in divisors(gpn)]
    for c in cands:
        if not placement_admissible(c, g_pipe, gd, gr, gc, gpn):
            continue
        p = placement_perm(c, g_pipe, gd, gr, gc, gpn)
        if p in seen:
            continue
        seen.append(p)
        out.append(c)
    return out


def reprice(machine, progs, perm):
    """Mirror of ``CommWorld::price_with`` (the ``sim::PlacedWorld``
    re-pricing): for every distinct logical group of an identity-built
    program, the per-node occupancy of its *placed* members — the one
    input ``ring_bw_lat`` needs.  Feeding this to ``simulate(...,
    pricing=...)`` must equal the ``place_programs`` rebuild bitwise."""
    out = {}
    for ops in progs:
        for op in ops:
            grp = op[4]
            if grp is not None and grp not in out:
                out[grp] = machine.members_per_node([perm[r] for r in grp])
    return out


def place_programs(progs, perm):
    """Mirror of the placed CommWorld registration: group member lists
    are mapped logical->physical so ``members_per_node`` (and from it
    the ring bandwidth share and P2p link selection) prices the placed
    ranks; group sizes, tags and rendezvous identity are untouched."""
    out = []
    for ops in progs:
        nops = []
        for (kind, a, b, tg, grp, stream, deps) in ops:
            if grp is not None:
                grp = tuple(perm[r] for r in grp)
            nops.append((kind, a, b, tg, grp, stream, deps))
        out.append(nops)
    return out


MASK64 = (1 << 64) - 1
GOLDEN64 = 0x9E3779B97F4A7C15


def splitmix64(x):
    """Mirror of spec::fault::splitmix64 (wrapping u64 arithmetic)."""
    z = (x + GOLDEN64) & MASK64
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK64
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK64
    return z ^ (z >> 31)


def jitter_factors(world, amplitude, seed=0):
    """Mirror of FaultSpec::jitter_factor for every rank: a deterministic
    per-rank compute slowdown in [1, 1 + amplitude)."""
    if amplitude <= 0.0:
        return [1.0] * world
    return [1.0 + amplitude * ((splitmix64(seed ^ ((r * GOLDEN64) & MASK64)) >> 11)
                               * (1.0 / (1 << 53)))
            for r in range(world)]


def fault_spec(mtbf_s, links=((0, 0.25),), jitter=0.0, jitter_seed=0,
               ckpt_interval_s=0.0, ckpt_bw=2e9, restart_s=180.0, mttr_s=1800.0,
               deaths=()):
    """Mirror of FaultSpec::with_mtbf with the tunable knobs the planner
    scoring reads.  ``links`` is ``[(node, bw_scale), ...]`` — onset
    times are irrelevant to the steady-state planner pricing.
    ``deaths`` (PR 10) is ``[(rank, at_s), ...]`` for the recovery path."""
    return {"mtbf_s": mtbf_s, "links": list(links), "jitter": jitter,
            "jitter_seed": jitter_seed, "ckpt_interval_s": ckpt_interval_s,
            "ckpt_bw": ckpt_bw, "restart_s": restart_s, "mttr_s": mttr_s,
            "deaths": list(deaths)}


def fault_price(machine, progs, perm, links):
    """Mirror of ``CommWorld::price_with_faults``: every distinct logical
    group priced at its placed ``ring_bw_lat``, then each degraded link
    multiplies the bandwidth of the *node-spanning* groups with a placed
    member on the sick node (node-local NVLink rings are unaffected)."""
    gpn = machine.gpus_per_node
    out = {}
    for ops in progs:
        for op in ops:
            grp = op[4]
            if grp is None or grp in out:
                continue
            placed = [perm[r] for r in grp] if perm is not None else list(grp)
            bw, lat = machine.ring_bw_lat(len(grp), machine.members_per_node(placed))
            nodes = [r // gpn for r in placed]
            spans = any(nd != nodes[0] for nd in nodes)
            for (sick, scale) in links:
                if spans and sick in nodes:
                    bw *= scale
            out[grp] = (bw, lat)
    return out


def checkpoint_cost_s(state_bytes_per_rank, ckpt_bw):
    """Mirror of comm_model::checkpoint_cost_s."""
    return 0.0 if ckpt_bw <= 0.0 else state_bytes_per_rank / ckpt_bw


def young_checkpoint_interval(cost_s, mtbf_s):
    """Mirror of comm_model::young_checkpoint_interval."""
    return (2.0 * max(cost_s, 0.0) * max(mtbf_s, 0.0)) ** 0.5


def checkpoint_efficiency(interval_s, cost_s, restart_s, mtbf_s):
    """Mirror of comm_model::checkpoint_efficiency."""
    if mtbf_s <= 0.0:
        return 1.0
    if interval_s <= 0.0:
        return 0.0
    util = interval_s / (interval_s + max(cost_s, 0.0))
    avail = 1.0 - (max(restart_s, 0.0) + interval_s / 2.0) / mtbf_s
    return min(max(util * avail, 0.0), 1.0)


def degraded_weight(mttr_s, mtbf_s):
    """Mirror of comm_model::degraded_weight."""
    if mtbf_s <= 0.0 or mttr_s <= 0.0:
        return 0.0
    return mttr_s / (mtbf_s + mttr_s)


def expected_secs_per_iter(t_healthy, t_degraded, w):
    """Mirror of comm_model::expected_secs_per_iter."""
    return (1.0 - w) * t_healthy + w * t_degraded


def ckpt_params(net, mode, mesh, g_pipe, spec):
    """Mirror of PlanRequest::ckpt_params: per-stage state bytes over
    the checkpoint bandwidth, interval fixed or Young-optimal."""
    sb = (state_bytes(net, mesh.g_tensor()) if mode == "rep"
          else state_bytes_sharded(net, mesh.g_tensor(), mesh.g_data)) / g_pipe
    cost = checkpoint_cost_s(sb, spec["ckpt_bw"])
    interval = (spec["ckpt_interval_s"] if spec["ckpt_interval_s"] > 0.0
                else young_checkpoint_interval(cost, spec["mtbf_s"]))
    return interval, cost


def expected_ips(net, mode, mesh, g_pipe, spec, mk_healthy, mk_degraded):
    """Mirror of the planner's fault-aware ranking key: checkpoint
    efficiency (per-layout cost) over the healthy/degraded expected
    seconds per iteration."""
    interval, cost = ckpt_params(net, mode, mesh, g_pipe, spec)
    eff = checkpoint_efficiency(interval, cost, spec["restart_s"], spec["mtbf_s"])
    w = degraded_weight(spec["mttr_s"], spec["mtbf_s"])
    return eff / expected_secs_per_iter(mk_healthy, mk_degraded, w)


def refine_faulted(net, batch, world, machine, mode, k, depth, pipes, m, spec,
                   placements=None):
    """Mirror of the fault-aware refined planner::PlanRequest (PR 7):
    every (G_pipe, mesh, placement) candidate simulated twice — healthy,
    and in the degraded world (``fault_price`` steady-state link pricing
    plus straggler jitter) — then ranked by expected iterations/sec.
    Returns ``(blind, aware)`` where ``blind`` is the healthy-makespan
    ranking (the fault-blind winner first) and ``aware`` the
    expected-throughput ranking, as
    ``[(p, mesh, placement, mk_healthy, mk_degraded, eips), ...]``."""
    gpn = machine.gpus_per_node
    base, base_vol = base_plan(candidates(net, batch, world, machine, mode))
    cands = pipelined_candidates(net, batch, world, machine, mode, pipes, m, k)
    if not any(p == 1 and mm.key() == base.key() for p, mm, _ in cands):
        cands.append((1, base, base_vol))
    jit = jitter_factors(world, spec["jitter"], spec["jitter_seed"])
    scored = []
    for p, mm, score in cands:
        if placements is not None:
            pls = [pl for pl in placements
                   if placement_admissible(pl, p, mm.g_data, mm.g_r, mm.g_c, gpn)]
            if not pls:
                pls = ["column-major"]
        else:
            pls = placement_search_set(p, mm.g_data, mm.g_r, mm.g_c, gpn)
        if p <= 1:
            progs = build_t3d(net, mm, batch, depth, machine, sharded=(mode == "sh"))
        else:
            progs = build_t3d_pipeline(net, mm, batch, depth, p, m, machine,
                                       sharded=(mode == "sh"))
        for pl in pls:
            perm = placement_perm(pl, p, mm.g_data, mm.g_r, mm.g_c, gpn)
            mk, _ = simulate(machine, place_programs(progs, perm))
            priced = fault_price(machine, progs, perm, spec["links"])
            fmk, _ = simulate(machine, progs, priced=priced, jitter=jit)
            ips = expected_ips(net, mode, mm, p, spec, mk, fmk)
            scored.append((p, mm, pl, mk, fmk, ips))
    blind = sorted(scored, key=lambda x: x[3])
    aware = sorted(scored, key=lambda x: (-x[5], x[3]))
    return blind, aware


def refine_placed(net, batch, world, machine, mode, k, depth, pipes, m,
                  placements=None):
    """Mirror of the refined planner::PlanRequest search: per-G_pipe
    gd-max shortlists x admissible placements, ranked by simulated
    makespan.  Returns (base, base_makespan, [(p, mesh, placement,
    score, makespan)]) sorted best-first."""
    gpn = machine.gpus_per_node
    base, base_vol = base_plan(candidates(net, batch, world, machine, mode))
    cands = pipelined_candidates(net, batch, world, machine, mode, pipes, m, k)
    if not any(p == 1 and mm.key() == base.key() for p, mm, _ in cands):
        cands.append((1, base, base_vol))
    jobs = []
    for p, mm, score in cands:
        if placements is not None:
            pls = [pl for pl in placements
                   if placement_admissible(pl, p, mm.g_data, mm.g_r, mm.g_c, gpn)]
            if not pls:
                # mirror of the Rust fallback: an explicit list that
                # admits nothing on this shape must not drop the mesh
                # from the ranking — score it under the default instead
                pls = ["column-major"]
        else:
            pls = placement_search_set(p, mm.g_data, mm.g_r, mm.g_c, gpn)
        jobs.append((p, mm, score, pls))
    if not any(p == 1 and mm.key() == base.key() and "column-major" in pls
               for p, mm, _, pls in jobs):
        # the anchor rides the base mesh's existing job as one more
        # placement (cands always contains the base — appended above)
        next(pls for p, mm, _, pls in jobs
             if p == 1 and mm.key() == base.key()).append("column-major")
    scored = []
    for p, mm, score, pls in jobs:
        for pl in pls:
            if p <= 1:
                progs = build_t3d(net, mm, batch, depth, machine, sharded=(mode == "sh"))
            else:
                progs = build_t3d_pipeline(net, mm, batch, depth, p, m, machine,
                                           sharded=(mode == "sh"))
            progs = place_programs(
                progs, placement_perm(pl, p, mm.g_data, mm.g_r, mm.g_c, gpn))
            mk, _ = simulate(machine, progs)
            scored.append((p, mm, pl, score, mk))
    scored.sort(key=lambda x: (x[4], x[3]))
    basemk = next(mk for p, mm, pl, _, mk in scored
                  if p == 1 and mm.key() == base.key() and pl == "column-major")
    return base, basemk, scored


def refine_pipelined(net, batch, world, machine, mode, k, depth, pipes, m):
    """Mirror of the refined planner::PlanRequest over pipeline depths
    with column-major placement."""
    base, base_vol = base_plan(candidates(net, batch, world, machine, mode))
    cands = pipelined_candidates(net, batch, world, machine, mode, pipes, m, k)
    if not any(p == 1 and mm.key() == base.key() for p, mm, _ in cands):
        cands.append((1, base, base_vol))
    scored = []
    for p, mm, score in cands:
        if p <= 1:
            progs = build_t3d(net, mm, batch, depth, machine, sharded=(mode == "sh"))
        else:
            progs = build_t3d_pipeline(net, mm, batch, depth, p, m, machine,
                                       sharded=(mode == "sh"))
        mk, _ = simulate(machine, progs)
        scored.append((p, mm, score, mk))
    scored.sort(key=lambda x: (x[3], x[2]))
    basemk = next(mk for p, mm, _, mk in scored if p == 1 and mm.key() == base.key())
    return base, basemk, scored


def recovery_cycle_ips(horizon_s, overhead_s, steady_ips):
    """Mirror of comm_model::recovery_cycle_ips: expected iterations/sec
    over one repair cycle of ``horizon_s`` (= MTBF + MTTR, failure to
    next failure) that opens with ``overhead_s`` of non-training
    recovery work, then runs at the ``steady_ips`` steady-state rate
    (the PR 7 fault-aware expected-throughput score, so policies and
    planner candidates share one currency)."""
    if horizon_s <= 0.0:
        return 0.0
    return steady_ips * max(horizon_s - overhead_s, 0.0) / horizon_s


def recovery_breakeven_mttr(mtbf_s, core_s, shrink_overhead_s,
                            full_ips, small_ips):
    """Mirror of comm_model::recovery_breakeven_mttr_s: the MTTR at
    which shrink-to-survivors overtakes wait-for-repair.  Over the cycle
    horizon H = MTBF + MTTR, waiting earns full_ips*(MTBF - core)
    iterations (independent of MTTR — the repair window is pure wait),
    while shrinking earns small_ips*(H - shrink_overhead), which grows
    with MTTR; the crossover is unique.  A dead survivor rate
    (``small_ips <= 0``) means shrinking never pays: infinite."""
    if small_ips <= 0.0:
        return float("inf")
    return max(full_ips * max(mtbf_s - core_s, 0.0) / small_ips
               - mtbf_s + shrink_overhead_s, 0.0)


def survivor_ranks(world, deaths, perm, gpn, evict_node=True):
    """Mirror of planner::recovery's survivor-world derivation: the dead
    logical ranks are removed from the world, and by default every rank
    placed on a casualty's physical node is evicted with it (a dead GPU
    condemns its host node; ``evict_node=False`` keeps the healthy
    neighbors).  Returns ``(survivor_world, dead_ranks)``."""
    dead = sorted({r for (r, _) in deaths if r < world})
    if dead and evict_node:
        phys = perm if perm is not None else list(range(world))
        sick = {phys[r] // gpn for r in dead}
        dead = sorted(r for r in range(world) if phys[r] // gpn in sick)
    return world - len(dead), dead


POLICY_ORDER = ("wait-for-repair", "shrink-to-survivors", "spare-node")


def recover(net, batch, world, machine, mode, k, depth, pipes, m,
            p, mesh, pl, mk_h, full_ips, spec, spares=0, replan_s=30.0,
            evict_node=True):
    """Mirror of planner::recovery (PR 10): given the running layout
    ``(p, mesh, pl)``, its healthy makespan, and its fault-aware
    steady-state score (``expected_ips``), price the recovery policies
    for the FaultSpec's death and rank them by expected iterations/sec
    over one repair cycle.

    Timeline ingredients, shared by every policy:
      * detection — the survivors' quiesce time from a dead-rank
        simulation of the placed program (StallError::at_s);
      * rollback — half the checkpoint interval (the expected work lost
        since the last checkpoint);
      * restart — ``spec["restart_s"]``;
    then per policy:
      * wait-for-repair: sit out MTTR, resume at the full-world
        steady-state rate;
      * shrink-to-survivors: re-shard the casualty's state over
        ``ckpt_bw``, pay ``replan_s``, continue at the survivor-world
        rate — the fault-aware refined winner of a full PlanRequest
        re-entry on the shrunken world (global batch preserved so
        iterations stay comparable units);
      * spare-node (``spares > 0``): same re-shard + replan cost, but
        resume at the full-world rate with no MTTR wait.

    Returns a dict with the per-policy timelines sorted best-first."""
    gpn = machine.gpus_per_node
    explicit = spec.get("deaths", [])
    deaths = [(r, t) for (r, t) in explicit if r < world]
    if not deaths and not explicit:
        # no scripted death: price the canonical casualty — rank 0,
        # mid-iteration (the expected case for a memoryless failure)
        deaths = [(0, 0.5 * mk_h)]
    perm = placement_perm(pl, p, mesh.g_data, mesh.g_r, mesh.g_c, gpn)
    detect = 0.0
    death_at = min(t for _, t in deaths) if deaths else 0.0
    if deaths:
        progs = (build_t3d(net, mesh, batch, depth, machine, sharded=(mode == "sh"))
                 if p <= 1 else
                 build_t3d_pipeline(net, mesh, batch, depth, p, m, machine,
                                    sharded=(mode == "sh")))
        dv = [float("inf")] * world
        for (r, t) in deaths:
            dv[r] = min(dv[r], t)
        q, _, stuck = simulate(machine, place_programs(progs, perm), deaths=dv)
        # a death past the iteration's end never stalls it: detection
        # then happens in a later (statistically identical) iteration
        detect = q if stuck else min(death_at, q)
    sw, dead = survivor_ranks(world, deaths, perm, gpn, evict_node)
    interval_h, cost_h = ckpt_params(net, mode, mesh, p, spec)
    core = detect + interval_h / 2.0 + spec["restart_s"]
    reshard = cost_h  # one rank's shard over ckpt_bw = one checkpoint write
    horizon = spec["mtbf_s"] + spec["mttr_s"]
    wait_over = core + spec["mttr_s"] if dead else 0.0
    policies = [("wait-for-repair", wait_over,
                 recovery_cycle_ips(horizon, wait_over, full_ips))]
    survivor = None
    breakeven = None
    if dead and sw >= 1:
        sans = dict(spec)
        sans["deaths"] = []
        _, aware = refine_faulted(net, batch, sw, machine, mode, k, depth,
                                  pipes, m, sans)
        sp, sm, spl, smk, sfmk, sips = aware[0]
        shrink_over = core + reshard + replan_s
        policies.append(("shrink-to-survivors", shrink_over,
                         recovery_cycle_ips(horizon, shrink_over, sips)))
        survivor = (sp, sm, spl, smk, sfmk, sips)
        breakeven = recovery_breakeven_mttr(spec["mtbf_s"], core, shrink_over,
                                            full_ips, sips)
    if dead and spares > 0:
        spare_over = core + reshard + replan_s
        policies.append(("spare-node", spare_over,
                         recovery_cycle_ips(horizon, spare_over, full_ips)))
    policies.sort(key=lambda x: (-x[2], POLICY_ORDER.index(x[0])))
    return {"deaths": deaths, "dead": dead, "death_at": death_at,
            "detect": detect, "survivor_world": sw, "survivor": survivor,
            "core": core, "reshard": reshard, "breakeven": breakeven,
            "policies": policies}


if __name__ == "__main__":
    # The configuration pinned by planner::tests::
    # refined_choice_differs_from_volume_choice_on_gpt9b_16.
    gpt9b = gpt_network(51200, 5632, 24, 32, 2048)
    base, basemk, scored = refine(gpt9b, 64, 16, polaris(), "rep", k=6)
    print(f"gpt9b/16 polaris replicated: Eq.-4 base {base.key()} at {basemk:.4f}s")
    for m, mk in scored:
        mark = " <- sim winner" if (m, mk) == scored[0] else ""
        print(f"  {m.key()}: {mk:.4f}s{mark}")
    assert scored[0][0].key() != base.key(), "expected the sim-refined choice to differ"
    assert scored[0][1] < basemk, "expected the sim-refined choice to be faster"
    print("ok: sim-refined choice differs from the Eq.-4 choice (as the Rust test pins)")

    # The 1F1B bubble acceptance pinned by strategies::tests::
    # pipelined_1f1b_idle_matches_analytic_bubble: compute-dominated
    # uniform stages -> idle fraction == (p-1)/(m+p-1) within 5%.
    class _L:
        def __init__(self, k, n, rows):
            self.name, self.k, self.n, self.rows, self.transposed = "l", k, n, rows, False

        def fwd_flops(self, samples):
            return 2.0 * samples * self.rows * self.k * self.n

        def weight_params(self):
            return float(self.k * self.n)

    uniform = Net([_L(4096, 4096, 128) for _ in range(8)], [], 8 * 4096 * 4096)
    stages, mb = 4, 8
    progs = build_t3d_pipeline(uniform, Mesh(1, 1, 1), 64, 1, stages, mb, polaris())
    mk, busy = simulate(polaris(), progs)
    idle = 1.0 - (sum(busy) / len(busy)) / mk
    bub = bubble_fraction(stages, mb)
    print(f"1f1b p={stages} m={mb}: idle {idle:.4f} vs analytic bubble {bub:.4f}")
    assert abs(idle / bub - 1.0) < 0.05, "1F1B idle fraction drifted from (p-1)/(m+p-1)"
    print("ok: simulated 1F1B bubble matches the analytic fraction (as the Rust test pins)")

    # The pipelined-refine acceptance pinned by planner::tests::
    # refined_pipelined_never_slower_than_pipeline_free_on_gpt9b_16.
    base, basemk, scored = refine_pipelined(gpt9b, 64, 16, polaris(), "rep",
                                            k=2, depth=2, pipes=[1, 2, 4], m=8)
    print(f"gpt9b/16 polaris replicated, G_pipe in {{1,2,4}}: "
          f"pipeline-free Eq.-4 base {base.key()} at {basemk:.4f}s")
    for p, mm, score, mk in scored:
        mark = " <- winner" if (p, mm, score, mk) == scored[0] else ""
        print(f"  G_pipe={p} {mm.key()}: {mk:.4f}s{mark}")
    assert scored[0][3] <= basemk, "pipelined refine must never lose to the Eq.-4 winner"
    print("ok: refined pipelined plan is never slower than the pipeline-free Eq.-4 winner")

    # The frontier golden plan pinned by planner::tests::
    # gpt80b_1024_frontier_plan_matches_ci_golden and diffed by the CI
    # bench-smoke job against ci/golden_plan_gpt80b_1024_frontier.json.
    gpt80b = gpt_network(51200, 16384, 24, 128, 2048)
    fbase, _ = base_plan(candidates(gpt80b, 1024, 1024, frontier(), "rep"))
    print(f"gpt80b/1024 frontier replicated plan: {fbase.key()} "
          f"(g_tensor {fbase.g_tensor()})")
    assert fbase.key() == (16, 4, 16), "frontier golden plan drifted"
    pbase, _ = base_plan(candidates(gpt80b, 1024, 1024, polaris(), "rep"))
    assert pbase.key() == (16, 4, 16), "polaris golden plan drifted"
    print("ok: gpt80b/1024 plans match the CI goldens (polaris + frontier)")

    # The placement pin: planner::tests::
    # placement_search_beats_column_major_on_gpt80b_128.  gpt80b on 128
    # Polaris GPUs (replicated): the Eq.-4 winner (2, 4, 16) leaves the
    # 16-member row rings strided at a 1/4 NIC share; the blocked2 node
    # tiling halves the column ring to the single-NIC cap but doubles
    # the dominant row share — ~26% faster, and the refined search
    # recommends it.
    base, basemk, scored = refine_placed(gpt80b, 1024, 128, polaris(), "rep",
                                         k=2, depth=2, pipes=[1], m=8)
    print(f"gpt80b/128 polaris rep, placement search: Eq.-4 base {base.key()} "
          f"column-major at {basemk:.4f}s")
    for p, mm, pl, score, mk in scored:
        mark = " <- winner" if (p, mm, pl, score, mk) == scored[0] else ""
        print(f"  G_pipe={p} {mm.key()} {pl}: {mk:.4f}s{mark}")
    wp, wm, wpl, _, wmk = scored[0]
    assert (wp, wm.key(), wpl) == (1, (2, 4, 16), "blocked2"), "placement winner drifted"
    assert wmk < basemk * 0.85, "blocked2 must beat column-major decisively"
    print("ok: blocked2 placement beats the column-major default on gpt80b/128 "
          "(as the Rust test pins)")

    # Fast refinement (PR 5): the refined candidate count must equal
    # shortlist x admissible placements — a shared-build bug that
    # silently dropped placements (or a filtered-empty mesh) would
    # shrink the search below this.
    cands128 = pipelined_candidates(gpt80b, 1024, 128, polaris(), "rep", [1], 8, 2)
    assert any(p == 1 and mm.key() == base.key() for p, mm, _ in cands128), \
        "the Eq.-4 base must be in the shortlist here (no anchor row added)"
    expected = sum(len(placement_search_set(p, mm.g_data, mm.g_r, mm.g_c, 4))
                   for p, mm, _ in cands128)
    assert len(scored) == expected, \
        f"refined candidates {len(scored)} != shortlist x placements {expected}"
    print(f"ok: refined candidate count = shortlist x admissible placements ({expected})")

    # The re-pricing invariant (PR 5): simulating an identity-built
    # program with per-group placed pricing equals the placed rebuild
    # bitwise — plain and pipelined (Send/Recv) programs alike.
    mesh94 = Mesh(2, 2, 4)
    progs = build_t3d(gpt9b, mesh94, 64, 2, polaris())
    for label in ("row-major", "blocked2", "blocked1"):
        perm = placement_perm(label, 1, 2, 2, 4, 4)
        a = simulate(polaris(), place_programs(progs, perm))
        b = simulate(polaris(), progs, pricing=reprice(polaris(), progs, perm))
        assert a == b, f"re-priced {label} drifted from the placed rebuild"
    pprogs = build_t3d_pipeline(gpt9b, Mesh(2, 1, 4), 64, 2, 2, 8, polaris())
    perm = placement_perm("depth-outer", 2, 2, 1, 4, 4)
    a = simulate(polaris(), place_programs(pprogs, perm))
    b = simulate(polaris(), pprogs, pricing=reprice(polaris(), pprogs, perm))
    assert a == b, "pipelined re-priced placement drifted from the placed rebuild"
    print("ok: re-priced placement simulation equals the placed rebuild (bitwise)")

    # The fault-aware divergence pin (PR 7): planner::tests::
    # fault_aware_ranking_differs_from_fault_blind_on_gpt9b_16.
    # GPT-9B / 16 Polaris GPUs, G_pipe over {1,2,4}, MTBF 900 s under
    # the default failure scenario (node 0 at 1/4 link bandwidth,
    # Young-optimal checkpoints): the fault-blind winner G_pipe=2
    # (2,1,4) spans nodes with its tensor rings and degrades ~30% on
    # the sick node; G_pipe=4 (1,1,4) is one stage per node — every
    # ring intra-node, only the stage-boundary P2p crosses — and
    # checkpoints a quarter of the per-stage state, so it wins the
    # expected-throughput ranking despite a slower healthy iteration.
    spec900 = fault_spec(900.0)
    blind, aware = refine_faulted(gpt9b, 64, 16, polaris(), "rep", 3, 2,
                                  [1, 2, 4], 8, spec900)
    print("gpt9b/16 polaris rep, G_pipe in {1,2,4}, MTBF 900 s (node0@0.25):")
    for row in aware[:4]:
        p, mm, pl, mk, fmk, ips = row
        tags = (" <- fault-blind" if row == blind[0] else "") + \
               (" <- fault-aware" if row == aware[0] else "")
        print(f"  G_pipe={p} {mm.key()} {pl}: healthy {mk:.4f}s "
              f"degraded {fmk:.4f}s expected {ips:.4f} iters/s{tags}")
    assert (blind[0][0], blind[0][1].key(), blind[0][2]) == \
        (2, (2, 1, 4), "column-major"), "fault-blind winner drifted"
    assert (aware[0][0], aware[0][1].key(), aware[0][2]) == \
        (4, (1, 1, 4), "column-major"), "fault-aware winner drifted"
    blind_row = next(r for r in aware if (r[0], r[1].key(), r[2]) ==
                     (blind[0][0], blind[0][1].key(), blind[0][2]))
    assert aware[0][5] > blind_row[5], \
        "the fault-aware pick must strictly beat the fault-blind winner"
    assert aware[0][3] > blind_row[3] and aware[0][4] < blind_row[4], \
        "graceful degradation: slower healthy, faster degraded"
    print("ok: fault-aware recommendation differs from the fault-blind one "
          "(as the Rust test pins)")

    # The headline mesh: the same tiling wins the paper-scale
    # gpt80b/1024 configuration (16, 4, 16) by >20%.
    mesh1024 = Mesh(16, 4, 16)
    mk_cm, _ = simulate(polaris(), build_t3d(gpt80b, mesh1024, 1024, 2, polaris()))
    progs = place_programs(build_t3d(gpt80b, mesh1024, 1024, 2, polaris()),
                           placement_perm("blocked2", 1, 16, 4, 16, 4))
    mk_b2, _ = simulate(polaris(), progs)
    print(f"gpt80b/1024 polaris (16,4,16): column-major {mk_cm:.2f}s "
          f"vs blocked2 {mk_b2:.2f}s")
    assert mk_b2 < mk_cm * 0.8, "the 1024-GPU blocked2 win drifted"
    print("ok: blocked2 wins the gpt80b/1024 headline mesh by >20%")

    # The fault-aware paper-scale golden (PR 7): the CI bench-smoke job
    # runs `plan --model gpt80b --gpus 1024 --machine polaris --refine 2
    # --mtbf 3600 --json` and diffs it against
    # ci/golden_plan_gpt80b_1024_faulted.json.  At this scale the
    # fault-aware and fault-blind rankings agree — every candidate spans
    # nodes, so the default failure scenario degrades them all roughly
    # proportionally — and the golden pins the fault-field plumbing:
    # the degraded makespan under node 0 at 1/4 link bandwidth, the
    # Young-optimal checkpoint cadence for the full replicated state,
    # and the expected-throughput score, all authored here.
    spec3600 = fault_spec(3600.0)
    progs1024 = build_t3d(gpt80b, mesh1024, 1024, 2, polaris())
    perm1024 = placement_perm("blocked2", 1, 16, 4, 16, 4)
    fmk_b2, _ = simulate(polaris(), progs1024,
                         priced=fault_price(polaris(), progs1024, perm1024,
                                            spec3600["links"]))
    interval, cost = ckpt_params(gpt80b, "rep", mesh1024, 1, spec3600)
    ips = expected_ips(gpt80b, "rep", mesh1024, 1, spec3600, mk_b2, fmk_b2)
    golden_path = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                               "..", "..", "ci",
                               "golden_plan_gpt80b_1024_faulted.json")
    with open(golden_path) as fh:
        golden = json.load(fh)
    assert (golden["g_data"], golden["g_r"], golden["g_c"]) == mesh1024.key(), \
        "faulted golden mesh drifted"
    assert golden["placement"] == "blocked2" and golden["mtbf_s"] == 3600, \
        "faulted golden scenario drifted"
    derived = {"makespan_s": mk_b2, "eq4_makespan_s": mk_cm,
               "fault_makespan_s": fmk_b2, "ckpt_interval_s": interval,
               "ckpt_cost_s": cost, "expected_iters_per_sec": ips}
    for key, val in derived.items():
        assert math.isclose(val, golden[key], rel_tol=1e-12), \
            f"faulted golden {key}: mirror {val!r} vs golden {golden[key]!r}"
    print(f"gpt80b/1024 faulted (MTBF 3600 s): degraded {fmk_b2:.2f}s, "
          f"ckpt every {interval:.1f}s ({cost:.2f}s each), "
          f"expected {ips:.5f} iters/s")
    print("ok: fault-aware gpt80b/1024 plan fields match the CI golden "
          "(ci/golden_plan_gpt80b_1024_faulted.json)")

    # The recovery golden (PR 10): the CI bench-smoke job runs
    # `replan --model gpt80b --gpus 1024 --machine polaris --mtbf 3600
    # --json` and diffs it against ci/golden_recovery_gpt80b_1024.json.
    # The canonical casualty (rank 0, mid-iteration) under blocked2
    # takes its whole node — ranks {0,1,4,5} — leaving a 1020-GPU
    # survivor world whose best re-plan is the awkward (17,4,15)
    # column-major mesh; at the default 1800 s MTTR the shrink timeline
    # still beats sitting out the repair, so the headline verdict is
    # shrink-to-survivors, with the wait/shrink breakeven near 769 s.
    # Every float in the golden is authored here.
    rep = recover(gpt80b, 1024, 1024, polaris(), "rep", 2, 2, [1], 8,
                  1, mesh1024, "blocked2", mk_b2, ips, spec3600)
    golden_path = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                               "..", "..", "ci",
                               "golden_recovery_gpt80b_1024.json")
    with open(golden_path) as fh:
        golden = json.load(fh)
    sp, sm, spl, smk, sfmk, sips = rep["survivor"]
    assert rep["deaths"] == [(0, 0.5 * mk_b2)], "canonical casualty drifted"
    assert rep["dead"] == [0, 1, 4, 5], "blocked2 node eviction drifted"
    assert (golden["death_rank"], golden["evicted_ranks"]) == (0, len(rep["dead"]))
    assert golden["survivor_world"] == rep["survivor_world"] == 1020
    assert (golden["survivor_g_data"], golden["survivor_g_r"],
            golden["survivor_g_c"]) == sm.key() == (17, 4, 15), \
        "survivor re-plan mesh drifted"
    assert golden["survivor_g_tensor"] == sm.g_tensor() and sp == 1
    assert golden["survivor_placement"] == spl == "column-major"
    best_name, _, best_ips = rep["policies"][0]
    assert golden["recovery_policy"] == best_name == "shrink-to-survivors", \
        "the headline recovery verdict drifted"
    wait_ips = next(pips for name, _, pips in rep["policies"]
                    if name == "wait-for-repair")
    derived = {"mttr_s": spec3600["mttr_s"],
               "death_at_s": rep["death_at"], "detect_s": rep["detect"],
               "shrunk_makespan_s": smk, "shrunk_iters_per_sec": sips,
               "wait_iters_per_sec": wait_ips,
               "recovery_iters_per_sec": best_ips,
               "recovery_breakeven_mttr_s": rep["breakeven"]}
    for key, val in derived.items():
        assert math.isclose(val, golden[key], rel_tol=1e-12), \
            f"recovery golden {key}: mirror {val!r} vs golden {golden[key]!r}"
    print(f"gpt80b/1024 recovery (MTTR 1800 s): detect {rep['detect']:.1f}s, "
          f"survivors 1020 -> (17,4,15) at {sips:.5f} iters/s steady; "
          f"{best_name} wins ({best_ips:.5f} vs wait {wait_ips:.5f} iters/s, "
          f"breakeven MTTR {rep['breakeven']:.0f}s)")
    print("ok: recovery decision matches the CI golden "
          "(ci/golden_recovery_gpt80b_1024.json)")

    # The shrink-vs-wait crossover (PR 10): planner::tests::
    # recovery_policy_crossover_on_gpt9b_40.  GPT-9B on 40 Polaris GPUs,
    # MTBF 3600 s: the canonical casualty takes node 0 (ranks 0-3) and
    # the 36-GPU survivor world re-plans onto G_pipe=2 (3,2,3).  The
    # verdict flips with the repair regime:
    #   * fast repairs (MTTR 60 s): waiting pays almost nothing beyond
    #     the shared core, so wait-for-repair wins and the breakeven
    #     MTTR (~917 s) sits far above the actual repair time;
    #   * slow repairs (MTTR 3600 s) with one hot spare: the spare
    #     resumes the full rate for shrink-grade overhead and wins
    #     outright, while plain shrinking still beats sitting out the
    #     hour-long repair (breakeven ~2608 s < 3600 s).
    # The full-world winner itself shifts with MTTR (the degraded
    # weight in the ranking), so each regime refines at its own spec —
    # exactly what PlanRequest::replan does.
    print("gpt9b/40 polaris rep, G_pipe in {1,2,4}, MTBF 3600 s:")
    for mttr, spares, want_winner, want_best, be_lo, be_hi in (
            (60.0, 0, (2, (5, 1, 4)), "wait-for-repair", 900.0, 935.0),
            (3600.0, 1, (4, (5, 1, 2)), "spare-node", 2500.0, 2700.0)):
        s = fault_spec(3600.0, mttr_s=mttr)
        _, aware = refine_faulted(gpt9b, 64, 40, polaris(), "rep", 3, 2,
                                  [1, 2, 4], 8, s)
        p, mm, pl, mk, fmk, fips = aware[0]
        assert (p, mm.key()) == want_winner and pl == "column-major", \
            f"mttr {mttr}: full-world winner drifted to G_pipe={p} {mm.key()} {pl}"
        rep = recover(gpt9b, 64, 40, polaris(), "rep", 3, 2, [1, 2, 4], 8,
                      p, mm, pl, mk, fips, s, spares=spares)
        assert rep["dead"] == [0, 1, 2, 3] and rep["survivor_world"] == 36
        assert rep["detect"] > rep["death_at"] >= 0.0, \
            "detection cannot precede the death"
        sp, sm, spl, smk, sfmk, sips = rep["survivor"]
        assert (sp, sm.key()) == (2, (3, 2, 3)), "survivor re-plan drifted"
        assert 0.0 < sips < fips, "the shrunken world cannot outrun the full one"
        names = [name for name, _, _ in rep["policies"]]
        by_name = {name: pips for name, _, pips in rep["policies"]}
        assert names[0] == want_best, \
            f"mttr {mttr}: best policy {names[0]}, expected {want_best}"
        assert ("spare-node" in names) == (spares > 0)
        assert be_lo < rep["breakeven"] < be_hi, \
            f"mttr {mttr}: breakeven {rep['breakeven']!r} outside ({be_lo}, {be_hi})"
        if mttr < rep["breakeven"]:
            assert by_name["wait-for-repair"] > by_name["shrink-to-survivors"], \
                "below the breakeven, waiting must beat shrinking"
        else:
            assert by_name["shrink-to-survivors"] > by_name["wait-for-repair"], \
                "above the breakeven, shrinking must beat waiting"
        print(f"  MTTR {mttr:.0f}s (spares {spares}): full winner G_pipe={p} "
              f"{mm.key()}, best {names[0]} "
              f"({', '.join(f'{n} {by_name[n]:.4f}' for n in names)} iters/s), "
              f"breakeven {rep['breakeven']:.0f}s")
    print("ok: the shrink-vs-wait verdict flips with the repair regime "
          "(as the Rust test pins)")

    # The two-tier embedding (PR 8): every flat Machine is a two-tier
    # fabric (node tier + one boundless NIC tier), and pricing through
    # the tier path must reproduce ring_bw_lat exactly — the float-equal
    # guarantee behind fabric::tests::
    # two_tier_embedding_prices_flat_machines_bit_for_bit.
    for fm in (perlmutter(), polaris(), frontier()):
        tm = type(fm)(fm.name, fm.gpus_per_node, fm.peak_flops, fm.mem_bytes,
                      fm.intra_bw, fm.intra_lat_s, fm.inter_bw_per_node, fm.nic_bw,
                      fm.inter_lat_s, fm.gemm_eff_max, fm.gemm_eff_halfdim,
                      tiers=flat_tiers(fm))
        gpn = fm.gpus_per_node
        shapes = [(0, 1), tuple(range(gpn)), (0, gpn), (0, 1, gpn, gpn + 1),
                  tuple(range(4 * gpn)), (0, 2 * gpn, 5 * gpn, 7 * gpn), (3,)]
        for grp in shapes:
            flat = fm.ring_bw_lat(len(grp), fm.members_per_node(grp))
            tier = tiered_bw_lat(tm, grp)
            assert flat == tier, f"{fm.name} {grp}: flat {flat} vs embedded {tier}"
    print("ok: the two-tier embedding prices every flat preset bit-for-bit")

    # The hierarchical-collectives crossover pin (PR 8), asserted in
    # Rust by strategies::tests::hierarchical_beats_flat_past_the_rail_
    # crossover: a 256 MB all-reduce over 2 members/node on perlmutter-xl
    # scanned across node counts.  Small groups win on halved latency
    # rounds; inside one 64-node rail the flat ring's 2-members-share-
    # 4-NICs price (50 GB/s) beats the decomposition's rail phase (the
    # rail link cap, 25 GB/s per direction is already below the halved
    # bytes' gain) by a hair; past the rail boundary both price at the
    # spine link and the decomposition's m-fold smaller cross-fabric
    # bytes win by ~2x.
    xl = perlmutter_xl()
    xlf = perlmutter_xl()
    xlf.flat_collectives = True
    B = 256e6
    flat_wins = []
    for n in (2, 4, 8, 16, 32, 64, 128, 256):
        members = tuple(r for k in range(n) for r in (8 * k, 8 * k + 1))
        progs = [[(AR, B, 0.0, 7, members, 1, ())] if r in set(members) else []
                 for r in range(8 * n)]
        t_hier, _ = simulate(xl, hierarchize(xl, progs))
        t_flat, _ = simulate(xlf, progs)
        if t_flat < t_hier:
            flat_wins.append(n)
        print(f"  AR 256 MB, 2/node x {n:>3} nodes: "
              f"flat {t_flat * 1e3:8.3f} ms  hier {t_hier * 1e3:8.3f} ms"
              f"  ({'flat' if t_flat < t_hier else 'hier'} wins)")
        if n == 128:
            assert t_flat > 1.5 * t_hier, \
                "the cross-rail hierarchical win must be decisive (>1.5x)"
    assert flat_wins == [16, 32, 64], \
        f"crossover drifted: flat wins at {flat_wins}, expected [16, 32, 64]"
    print("ok: hierarchical beats flat outside the single-rail window "
          "(flat wins exactly 16/32/64 nodes, as the Rust test pins)")

    # The tiered paper-scale golden (PR 8): the CI bench-smoke job runs
    # `plan --model gpt80b --gpus 1024 --machine perlmutter-xl --refine 2
    # --placements column-major --json` and diffs it against
    # ci/golden_plan_gpt80b_1024_xl.json (discrete fields exact, floats
    # within 5%; the golden's floats are authored here).  The refined
    # sweep on the tiered preset exercises the hierarchical path end to
    # end: every g_r=4 candidate's row rings put 2 members on each node
    # and decompose into intra-node RS -> cross-rail AR -> intra-node AG.
    xbase, xbasemk, xscored = refine(gpt80b, 1024, 1024, perlmutter_xl(), "rep",
                                     k=2, depth=2)
    print(f"gpt80b/1024 perlmutter-xl replicated: Eq.-4 base {xbase.key()} "
          f"at {xbasemk!r}s")
    for m, mk in xscored:
        mark = " <- sim winner" if (m, mk) == xscored[0] else ""
        print(f"  {m.key()}: {mk!r}s{mark}")
    xwin, xmk = xscored[0]
    golden_path = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                               "..", "..", "ci", "golden_plan_gpt80b_1024_xl.json")
    with open(golden_path) as fh:
        golden = json.load(fh)
    assert (golden["g_data"], golden["g_r"], golden["g_c"]) == xwin.key(), \
        f"xl golden mesh drifted: mirror {xwin.key()}"
    assert golden["g_tensor"] == xwin.g_tensor(), "xl golden g_tensor drifted"
    assert (golden["model"], golden["machine"]) == ("gpt80b", "perlmutter-xl")
    assert golden["gpus"] == golden["world"] == 1024
    assert golden["placement"] == "column-major", "xl golden placement drifted"
    for key, val in (("makespan_s", xmk), ("eq4_makespan_s", xbasemk)):
        assert math.isclose(val, golden[key], rel_tol=1e-12), \
            f"xl golden {key}: mirror {val!r} vs golden {golden[key]!r}"
    print("ok: tiered gpt80b/1024 refined plan matches the CI golden "
          "(ci/golden_plan_gpt80b_1024_xl.json)")
