"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles.

hypothesis sweeps shapes/dtypes; every property asserts allclose against
``compile.kernels.ref``.  This is the core correctness signal for the
compute layer — everything the Rust coordinator executes is built from
these kernels.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import matmul as mm
from compile.kernels import fused_linear as fl
from compile.kernels import layernorm as ln
from compile.kernels import softmax_xent as sx
from compile.kernels import ref

settings.register_profile("kernels", deadline=None, max_examples=25)
settings.load_profile("kernels")

DIMS = st.sampled_from([1, 2, 3, 4, 8, 16, 24, 48, 64, 96, 128, 160, 256])
SMALL_DIMS = st.sampled_from([1, 2, 4, 8, 16, 32, 64])
F_DTYPES = st.sampled_from([np.float32, jnp.bfloat16])


def _rand(rng, shape, dtype=np.float32):
    x = rng.standard_normal(shape, dtype=np.float32)
    return jnp.asarray(x).astype(dtype)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------- matmul

@given(m=DIMS, k=DIMS, n=DIMS, seed=st.integers(0, 2**31 - 1), dtype=F_DTYPES)
def test_matmul_matches_ref(m, k, n, seed, dtype):
    rng = np.random.default_rng(seed)
    a, b = _rand(rng, (m, k), dtype), _rand(rng, (k, n), dtype)
    got = mm.matmul(a, b)
    want = ref.matmul(a, b)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **_tol(dtype)
    )


@given(m=SMALL_DIMS, k=SMALL_DIMS, n=SMALL_DIMS, seed=st.integers(0, 2**31 - 1))
def test_matmul_transposed_helpers(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x = _rand(rng, (m, k))
    dy = _rand(rng, (m, n))
    w = _rand(rng, (k, n))
    np.testing.assert_allclose(
        np.asarray(mm.matmul_bt(dy, w)), np.asarray(dy) @ np.asarray(w).T, rtol=2e-5, atol=2e-5
    )
    np.testing.assert_allclose(
        np.asarray(mm.matmul_at(x, dy)), np.asarray(x).T @ np.asarray(dy), rtol=2e-5, atol=2e-5
    )


@given(m=DIMS, k=DIMS, n=DIMS)
def test_pick_blocks_divide_and_fit(m, k, n):
    bm, bk, bn = mm.pick_blocks(m, k, n)
    assert m % bm == 0 and k % bk == 0 and n % bn == 0
    assert mm.vmem_bytes(m, k, n) <= mm.VMEM_BUDGET
    assert 0.0 < mm.mxu_utilization_estimate(m, k, n) <= 1.0


def test_pick_blocks_prefers_mxu_multiples():
    bm, bk, bn = mm.pick_blocks(2048, 1024, 2048)
    assert bm % 128 == 0 and bk % 128 == 0 and bn % 128 == 0


def test_matmul_shape_mismatch_raises():
    a = jnp.zeros((4, 5), jnp.float32)
    b = jnp.zeros((6, 4), jnp.float32)
    with pytest.raises(ValueError):
        mm.matmul(a, b)


# ----------------------------------------------------------- fused linear

@given(
    m=SMALL_DIMS, k=SMALL_DIMS, n=SMALL_DIMS,
    act=st.sampled_from(["none", "relu", "gelu"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_fused_linear_matches_ref(m, k, n, act, seed):
    rng = np.random.default_rng(seed)
    a, b = _rand(rng, (m, k)), _rand(rng, (k, n))
    bias = _rand(rng, (n,))
    got = fl.fused_linear(a, b, bias, act)
    want = ref.fused_linear(a, b, bias, act)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=3e-5, atol=3e-5)


def test_fused_linear_bad_act():
    z = jnp.zeros((2, 2), jnp.float32)
    with pytest.raises(ValueError):
        fl.fused_linear(z, z, jnp.zeros((2,), jnp.float32), "swish")


# -------------------------------------------------------------- layernorm

@given(m=SMALL_DIMS, h=st.sampled_from([2, 4, 8, 32, 128, 160]), seed=st.integers(0, 2**31 - 1))
def test_layernorm_matches_ref(m, h, seed):
    rng = np.random.default_rng(seed)
    x = _rand(rng, (m, h))
    g, b = _rand(rng, (h,)), _rand(rng, (h,))
    got = ln.layernorm(x, g, b)
    want = ref.layernorm(x, g, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


@given(
    m=SMALL_DIMS,
    h=st.sampled_from([8, 32, 64, 128]),
    shards=st.sampled_from([1, 2, 4]),
    seed=st.integers(0, 2**31 - 1),
)
def test_layernorm_sharded_protocol(m, h, shards, seed):
    """Column-sharded LN: local partials + summed stats == serial LN.

    This is exactly the 2-float-per-row all-reduce protocol the Rust
    coordinator runs between ln_partials and ln_apply."""
    rng = np.random.default_rng(seed)
    x = _rand(rng, (m, h))
    g, b = _rand(rng, (h,)), _rand(rng, (h,))
    cols = h // shards
    parts = [x[:, i * cols:(i + 1) * cols] for i in range(shards)]
    stats = sum(np.asarray(ln.ln_partials(p)) for p in parts)
    stats = jnp.asarray(stats)
    out = np.concatenate(
        [
            np.asarray(
                ln.ln_apply(p, stats, g[i * cols:(i + 1) * cols], b[i * cols:(i + 1) * cols], total_h=h)
            )
            for i, p in enumerate(parts)
        ],
        axis=1,
    )
    want = np.asarray(ref.layernorm(x, g, b))
    np.testing.assert_allclose(out, want, rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------ softmax xent

@given(m=SMALL_DIMS, v=st.sampled_from([2, 8, 32, 128]), seed=st.integers(0, 2**31 - 1))
def test_softmax_xent_matches_ref(m, v, seed):
    rng = np.random.default_rng(seed)
    logits = _rand(rng, (m, v))
    labels = jnp.asarray(rng.integers(0, v, m).astype(np.int32))
    l1, d1 = sx.softmax_xent(logits, labels)
    l2, d2 = ref.softmax_xent(logits, labels)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2), rtol=1e-5, atol=1e-6)


@given(
    m=SMALL_DIMS,
    v_per=st.sampled_from([4, 16, 64]),
    shards=st.sampled_from([1, 2, 4]),
    seed=st.integers(0, 2**31 - 1),
)
def test_softmax_xent_vocab_sharded_protocol(m, v_per, shards, seed):
    """Vocab-parallel xent: two tiny all-reduces (max, sum-exp) + local
    loss/grad per shard reassemble to the serial result — the contract the
    Rust coordinator relies on for the output head."""
    rng = np.random.default_rng(seed)
    v = v_per * shards
    logits = _rand(rng, (m, v))
    labels = jnp.asarray(rng.integers(0, v, m).astype(np.int32))
    shard_logits = [logits[:, s * v_per:(s + 1) * v_per] for s in range(shards)]
    # coordinator protocol
    gmax = jnp.asarray(np.max([np.asarray(sx.xent_rowmax(s)) for s in shard_logits], axis=0))
    gsum = jnp.asarray(np.sum([np.asarray(sx.xent_sumexp(s, gmax)) for s in shard_logits], axis=0))
    loss = 0.0
    dparts = []
    for s in range(shards):
        off = jnp.asarray(np.array([s * v_per], np.int32))
        lv, dl = sx.xent_loss_grad(shard_logits[s], labels, gmax, gsum, off, m)
        loss += float(jnp.sum(lv))
        dparts.append(np.asarray(dl))
    l2, d2 = ref.softmax_xent(logits, labels)
    np.testing.assert_allclose(loss, float(l2), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.concatenate(dparts, axis=1), np.asarray(d2), rtol=1e-5, atol=1e-6)


def test_xent_gradient_sums_to_zero_per_row():
    rng = np.random.default_rng(7)
    logits = _rand(rng, (16, 32))
    labels = jnp.asarray(rng.integers(0, 32, 16).astype(np.int32))
    _, d = sx.softmax_xent(logits, labels)
    np.testing.assert_allclose(np.asarray(d).sum(axis=1), 0.0, atol=1e-6)
