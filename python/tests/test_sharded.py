"""The paper's core numerics claim, pinned in Python before Rust runs it:
Algorithm 1 + the §4.1 transposed layout reproduce the serial model
exactly (up to f32 reduction reordering) on every grid decomposition.

These tests exercise compile.sharded_ref — the executable spec the Rust
coordinator mirrors collective-for-collective."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import model as M
from compile import sharded_ref as S

settings.register_profile("sharded", deadline=None, max_examples=6)
settings.load_profile("sharded")

CFG = M.CONFIGS["gpt-nano"]
GRIDS = [(1, 1), (2, 1), (1, 2), (2, 2), (4, 2), (2, 4), (4, 4)]


def _setup(seed, mb=2):
    params = M.init_params(CFG, seed=seed % 997)
    rng = np.random.default_rng(seed)
    tokens = jnp.asarray(rng.integers(0, CFG.vocab, (mb, CFG.seq)).astype(np.int32))
    labels = jnp.asarray(rng.integers(0, CFG.vocab, mb * CFG.seq).astype(np.int32))
    return params, tokens, labels


@pytest.mark.parametrize("g_r,g_c", GRIDS)
def test_sharded_loss_and_grads_match_serial(g_r, g_c):
    params, tokens, labels = _setup(1234)
    loss_s, grads_s, _ = M.serial_forward_backward(CFG, params, tokens, labels, backend="jnp")
    grid = S.shard_params(CFG, params, g_r, g_c)
    loss, gg = S.grid_forward_backward(CFG, grid, tokens, labels, g_r, g_c)
    assert abs(loss - float(loss_s)) < 1e-4
    raw = [[{k: v for k, v in gg[i][j].items()} for j in range(g_c)] for i in range(g_r)]
    ag = S.assemble_grads(CFG, raw, g_r, g_c)
    for k in grads_s:
        scale = np.abs(np.asarray(grads_s[k])).max() + 1e-8
        np.testing.assert_allclose(
            np.asarray(ag[k]) / scale, np.asarray(grads_s[k]) / scale,
            atol=2e-5, err_msg=f"{k} at grid {g_r}x{g_c}",
        )


@given(seed=st.integers(0, 2**31 - 1))
def test_shard_params_roundtrip(seed):
    """shard + assemble is the identity on the parameter values."""
    params = M.init_params(CFG, seed=seed % 997)
    g_r, g_c = 2, 2
    grid = S.shard_params(CFG, params, g_r, g_c)
    arrays = [[{k: v.array for k, v in grid[i][j].items()} for j in range(g_c)]
              for i in range(g_r)]
    back = S.assemble_grads(CFG, arrays, g_r, g_c)
    for k in params:
        np.testing.assert_array_equal(np.asarray(back[k]), np.asarray(params[k]), err_msg=k)


@pytest.mark.parametrize("g_r,g_c", [(2, 2), (4, 2)])
def test_ownership_covers_each_param_exactly_once(g_r, g_c):
    """Summing owned shard sizes must equal the total param count — the
    invariant behind the coordinator's gradient-norm accounting."""
    params = M.init_params(CFG)
    grid = S.shard_params(CFG, params, g_r, g_c)
    owned = 0
    for i in range(g_r):
        for j in range(g_c):
            for sh in grid[i][j].values():
                if sh.owned:
                    owned += int(np.prod(sh.array.shape))
    assert owned == CFG.params()


def test_replicated_shards_are_identical_across_their_replication_dim():
    params = M.init_params(CFG)
    g_r, g_c = 2, 4
    grid = S.shard_params(CFG, params, g_r, g_c)
    # row-sharded (replicated over columns)
    for i in range(g_r):
        for j in range(1, g_c):
            np.testing.assert_array_equal(
                np.asarray(grid[i][j]["lnf_g"].array), np.asarray(grid[i][0]["lnf_g"].array))
            np.testing.assert_array_equal(
                np.asarray(grid[i][j]["wemb"].array), np.asarray(grid[i][0]["wemb"].array))
    # column-sharded (replicated over rows)
    for j in range(g_c):
        for i in range(1, g_r):
            np.testing.assert_array_equal(
                np.asarray(grid[i][j]["head_b"].array), np.asarray(grid[0][j]["head_b"].array))


def test_overdecomposition_subshards_sum_to_full_batch_grads():
    """§4.2: running the two depth sub-shards independently and summing
    their gradients equals one full-shard pass (total_rows fixed global) —
    the invariant that makes the round-robin scheduler correct."""
    params, tokens, labels = _setup(77, mb=4)
    g_r = g_c = 2
    m_total = tokens.shape[0] * CFG.seq
    grid = S.shard_params(CFG, params, g_r, g_c)
    loss_full, gg_full = S.grid_forward_backward(
        CFG, grid, tokens, labels, g_r, g_c, total_rows=m_total)
    # split into 2 sub-shards along the batch dim
    t1, t2 = tokens[:2], tokens[2:]
    l1, l2 = labels[: 2 * CFG.seq], labels[2 * CFG.seq:]
    lossA, ggA = S.grid_forward_backward(CFG, grid, t1, l1, g_r, g_c, total_rows=m_total)
    lossB, ggB = S.grid_forward_backward(CFG, grid, t2, l2, g_r, g_c, total_rows=m_total)
    assert abs((lossA + lossB) - loss_full) < 1e-4
    for i in range(g_r):
        for j in range(g_c):
            for k in gg_full[i][j]:
                a = np.asarray(ggA[i][j][k]) + np.asarray(ggB[i][j][k])
                b = np.asarray(gg_full[i][j][k])
                scale = np.abs(b).max() + 1e-8
                np.testing.assert_allclose(a / scale, b / scale, atol=2e-5,
                                           err_msg=f"{k}@({i},{j})")
