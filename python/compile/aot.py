"""AOT compiler: lower every L2 entry point to HLO text + manifest.json.

Usage (from python/):

    python -m compile.aot --config gpt-nano --grid 2x2 --batch 8 \
        --depth 2 --backend jnp --out ../artifacts

Emits ``<out>/<config>_r<G_r>c<G_c>d<depth>b<batch>_<backend>/``
containing one ``<entry>.hlo.txt`` per entry point plus ``manifest.json``
describing shapes/dtypes, which the Rust runtime consumes.

Interchange format is HLO *text*, not serialized HloModuleProto: jax >=
0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version behind the Rust ``xla`` crate) rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Python runs ONLY here, at build time.  The Rust binary is self-contained
once the artifacts exist; ``make artifacts`` is a no-op when inputs are
unchanged (mtime-based, via Make).
"""

import argparse
import functools
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _aval(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _dt(a) -> str:
    return {"float32": "f32", "int32": "i32", "bfloat16": "bf16"}[str(a.dtype)]


def build_entries(cfg: M.ModelConfig, grid: M.GridConfig, batch: int, backend: str):
    """The full entry-point table for one (config, grid, batch) tuple.

    Returns list of (name, fn, avals, n_outputs).  Shapes follow
    sharded_ref.py exactly; see that module for the collective protocol
    between entries.
    """
    M.validate(cfg, grid, batch)
    h, f, v, s = cfg.hidden, cfg.ffn, cfg.vocab, cfg.seq
    hr, hc = h // grid.g_r, h // grid.g_c
    tc, fc, vc = 3 * h // grid.g_c, f // grid.g_c, v // grid.g_c
    hl, dh = cfg.heads // grid.g_c, cfg.head_dim
    mb = batch // (grid.g_data * grid.depth)  # sequences per exec
    m = mb * s                                # rows per exec
    total_rows = batch * s                    # global mean divisor
    f32, i32 = jnp.float32, jnp.int32

    B = backend
    ent = []

    def add(name, fn, avals, n_out):
        ent.append((name, fn, avals, n_out))

    add("embed_fwd", M.embed_fwd, [_aval((mb, s), i32), _aval((v, hr)), _aval((s, hr))], 1)
    # NOTE: tokens are not an input here — XLA prunes unused parameters at
    # compile time, so the entry signature must only carry live arguments.
    add("embed_bwd_pos", lambda dx: dx.reshape(mb, s, hr).sum(axis=0),
        [_aval((m, hr))], 1)
    add("embed_bwd_table", functools.partial(M.embed_bwd_table, vocab=v),
        [_aval((mb, s), i32), _aval((m, hr))], 1)

    add("ln_stats", M.ln_stats, [_aval((m, hr))], 1)
    add("ln_apply", functools.partial(M.ln_apply, total_h=h),
        [_aval((m, hr)), _aval((m, 2)), _aval((hr,)), _aval((hr,))], 1)
    add("ln_bwd_stats", functools.partial(M.ln_bwd_stats, total_h=h),
        [_aval((m, hr)), _aval((m, 2)), _aval((hr,)), _aval((m, hr))], 1)
    add("ln_bwd_finish", functools.partial(M.ln_bwd_finish, total_h=h),
        [_aval((m, hr)), _aval((m, 2)), _aval((hr,)), _aval((m, hr)), _aval((m, 2))], 3)

    for tag, k, n in [
        ("qkv", hr, tc), ("proj", hc, hr), ("mlp1", hr, fc),
        ("mlp2", fc, hr), ("head", hr, vc),
    ]:
        add(f"mm_{tag}_fwd", functools.partial(M.mm_fwd, backend=B),
            [_aval((m, k)), _aval((k, n))], 1)
        add(f"mm_{tag}_dx", functools.partial(M.mm_dx, backend=B),
            [_aval((m, n)), _aval((k, n))], 1)
        add(f"mm_{tag}_dw", functools.partial(M.mm_dw, backend=B),
            [_aval((m, k)), _aval((m, n))], 1)

    add("attn_fwd",
        functools.partial(M.attn_fwd, mb=mb, seq=s, heads_local=hl, head_dim=dh),
        [_aval((m, 3 * hl * dh))], 1)
    add("attn_bwd",
        functools.partial(M.attn_bwd, mb=mb, seq=s, heads_local=hl, head_dim=dh),
        [_aval((m, 3 * hl * dh)), _aval((m, hl * dh))], 1)

    gelu_b = jnp.zeros((fc,), f32)
    add("gelu_fwd", lambda u: M.bias_act_fwd(u, jnp.zeros((u.shape[1],), u.dtype), "gelu"),
        [_aval((m, fc))], 1)
    add("gelu_bwd", lambda u, dv: M.bias_act_bwd(u, jnp.zeros((u.shape[1],), u.dtype), dv, "gelu")[0],
        [_aval((m, fc)), _aval((m, fc))], 1)

    add("xent_rowmax", M.xent_rowmax, [_aval((m, vc))], 1)
    add("xent_sumexp", M.xent_sumexp, [_aval((m, vc)), _aval((m,))], 1)
    add("xent_loss_grad", functools.partial(M.xent_loss_grad, total_rows=total_rows),
        [_aval((m, vc)), _aval((m,), i32), _aval((m,)), _aval((m,)), _aval((1,), i32)], 2)

    return ent, dict(rows_per_exec=m, seqs_per_exec=mb, total_rows=total_rows)


def lower_all(cfg: M.ModelConfig, grid: M.GridConfig, batch: int, backend: str,
              out_dir: str, quiet: bool = False):
    entries, meta = build_entries(cfg, grid, batch, backend)
    os.makedirs(out_dir, exist_ok=True)
    manifest = {
        "model": {
            "name": cfg.name, "vocab": cfg.vocab, "hidden": cfg.hidden,
            "layers": cfg.layers, "heads": cfg.heads, "seq": cfg.seq,
            "head_dim": cfg.head_dim, "ffn": cfg.ffn, "params": cfg.params(),
        },
        "grid": {
            "g_data": grid.g_data, "g_r": grid.g_r, "g_c": grid.g_c,
            "depth": grid.depth,
        },
        "batch": batch,
        "backend": backend,
        **meta,
        "entries": [],
    }
    for name, fn, avals, n_out in entries:
        lowered = jax.jit(fn).lower(*avals)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as fh:
            fh.write(text)
        out_avals = jax.eval_shape(fn, *avals)
        if not isinstance(out_avals, (tuple, list)):
            out_avals = (out_avals,)
        manifest["entries"].append({
            "name": name,
            "file": fname,
            "inputs": [{"shape": list(a.shape), "dtype": _dt(a)} for a in avals],
            "outputs": [{"shape": list(a.shape), "dtype": _dt(a)} for a in out_avals],
        })
        if not quiet:
            print(f"  lowered {name:18s} ({len(text)//1024} KiB)")
    with open(os.path.join(out_dir, "manifest.json"), "w") as fh:
        json.dump(manifest, fh, indent=1)
    return manifest


def artifact_dirname(cfg_name: str, grid: M.GridConfig, batch: int, backend: str) -> str:
    return f"{cfg_name}_r{grid.g_r}c{grid.g_c}d{grid.depth}b{batch}_{backend}"


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--config", default="gpt-nano", choices=sorted(M.CONFIGS))
    ap.add_argument("--grid", default="1x1", help="G_r x G_c, e.g. 2x2")
    ap.add_argument("--g-data", type=int, default=1)
    ap.add_argument("--depth", type=int, default=1,
                    help="overdecomposition degree (paper §4.2 uses 2)")
    ap.add_argument("--batch", type=int, default=8, help="global batch (sequences)")
    ap.add_argument("--backend", default="jnp", choices=["jnp", "pallas"])
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args(argv)

    g_r, g_c = (int(t) for t in args.grid.lower().split("x"))
    grid = M.GridConfig(g_data=args.g_data, g_r=g_r, g_c=g_c, depth=args.depth)
    cfg = M.CONFIGS[args.config]
    out_dir = os.path.join(args.out, artifact_dirname(cfg.name, grid, args.batch, args.backend))
    print(f"AOT: {cfg.name} grid={g_r}x{g_c} g_data={grid.g_data} depth={grid.depth} "
          f"batch={args.batch} backend={args.backend} -> {out_dir}")
    lower_all(cfg, grid, args.batch, args.backend, out_dir)
    print("done")


if __name__ == "__main__":
    main()
