"""Layer-2 JAX model: per-shard GPT segment functions for Algorithm 1.

The Rust coordinator owns every collective; this module defines the *local*
computation between collectives as standalone jittable functions, each of
which aot.py lowers to its own HLO artifact.  The decomposition follows the
paper exactly:

  * activations are column-sharded: at a block boundary ``x_i`` is the
    ``H/G_r`` column slice held by every GPU of grid row ``i``
    (replicated across the row's ``G_c`` members);
  * weights are 2-D sharded ``(G_r x G_c)``; *alternate* layers store the
    transposed layout of §4.1 (the attention out-projection and the second
    MLP matmul), which flips the forward all-reduce from the column
    communicator to the row communicator and removes all layer-boundary
    redistribution;
  * LayerNorm over the sharded hidden dim uses the 2-floats-per-row
    partial-stats protocol (ln_stats -> AR -> ln_apply), and its backward
    the symmetric one;
  * the output head is a plain Algorithm-1 FC over the vocabulary, with
    the fused vocab-parallel softmax-xent protocol of kernels/softmax_xent.

With ``G_r == G_c == 1`` the same entry points compose into the serial
reference model — there is deliberately no separate serial code path, so
the Fig.-6 loss-equivalence experiment compares the *same* numerics under
different decompositions.

Every matmul routes through the L1 Pallas kernel (``kernels.matmul``);
``backend='jnp'`` swaps in the pure-jnp oracle, which lowers to a single
``dot`` HLO (used for the fast CPU training path; the pallas and jnp
artifacts are asserted allclose in python/tests).
"""

import dataclasses
import functools
import math
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from .kernels import matmul as mm_kernel
from .kernels import layernorm as ln_kernel
from .kernels import softmax_xent as sx_kernel
from .kernels import ref as kref


# --------------------------------------------------------------------------
# Configuration
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """GPT architecture hyper-parameters (full, unsharded dims)."""

    name: str
    vocab: int
    hidden: int
    layers: int
    heads: int
    seq: int

    @property
    def ffn(self) -> int:
        return 4 * self.hidden

    @property
    def head_dim(self) -> int:
        return self.hidden // self.heads

    def params(self) -> int:
        """Total parameter count (embeddings + blocks + final LN + head)."""
        h, f, v, s = self.hidden, self.ffn, self.vocab, self.seq
        per_block = (
            h * 3 * h + 3 * h        # qkv + bias
            + h * h + h              # proj + bias
            + h * f + f              # mlp1 + bias
            + f * h + h              # mlp2 + bias
            + 4 * h                  # 2 x LN gamma/beta
        )
        return v * h + s * h + self.layers * per_block + 2 * h + h * v + v


@dataclasses.dataclass(frozen=True)
class GridConfig:
    """The 4-D decomposition: G = g_data * g_r * g_c, depth-way
    overdecomposition of each group's batch shard (§4.2)."""

    g_data: int = 1
    g_r: int = 1
    g_c: int = 1
    depth: int = 1  # sub-shards per batch shard (paper uses 2)

    @property
    def g_tensor(self) -> int:
        return self.g_r * self.g_c

    @property
    def world(self) -> int:
        return self.g_data * self.g_tensor


# Registry of live-runnable configs (the table-3 style giants are described
# on the Rust side for the simulator; these are the ones we actually train).
CONFIGS: Dict[str, ModelConfig] = {
    # smoke-test scale
    "gpt-nano": ModelConfig("gpt-nano", vocab=256, hidden=64, layers=2, heads=4, seq=32),
    # ~10M params; fast CPU demo scale
    "gpt-micro": ModelConfig("gpt-micro", vocab=1024, hidden=256, layers=4, heads=8, seq=128),
    # ~27M params
    "gpt-mini": ModelConfig("gpt-mini", vocab=4096, hidden=512, layers=8, heads=8, seq=128),
    # ~124M params (GPT-2 small shape): the end-to-end driver target
    "gpt-100m": ModelConfig("gpt-100m", vocab=8192, hidden=768, layers=12, heads=12, seq=256),
}


def validate(cfg: ModelConfig, grid: GridConfig, batch: int) -> None:
    """Check that the decomposition divides the model evenly."""
    if cfg.hidden % (grid.g_r * 1) != 0:
        raise ValueError(f"hidden {cfg.hidden} % g_r {grid.g_r} != 0")
    if cfg.hidden % grid.g_c != 0 or cfg.ffn % grid.g_c != 0:
        raise ValueError(f"hidden/ffn not divisible by g_c {grid.g_c}")
    if cfg.ffn % grid.g_r != 0:
        raise ValueError(f"ffn {cfg.ffn} % g_r {grid.g_r} != 0")
    if cfg.heads % grid.g_c != 0:
        raise ValueError(f"heads {cfg.heads} % g_c {grid.g_c} != 0")
    if cfg.vocab % grid.g_c != 0 or cfg.vocab % grid.g_r != 0:
        raise ValueError(f"vocab {cfg.vocab} not divisible by grid")
    if batch % (grid.g_data * grid.depth) != 0:
        raise ValueError(
            f"batch {batch} % (g_data*depth)={grid.g_data * grid.depth} != 0"
        )


# --------------------------------------------------------------------------
# Segment functions (the units Rust executes between collectives)
# --------------------------------------------------------------------------


def matmul_fn(backend: str):
    if backend == "pallas":
        return mm_kernel.matmul
    if backend == "jnp":
        return kref.matmul
    raise ValueError(f"backend must be 'pallas' or 'jnp', got {backend!r}")


def embed_fwd(tokens, wemb, wpos):
    """(mb, S) int32, (V, h_r), (S, h_r) -> (mb*S, h_r) local embedding."""
    mb, s = tokens.shape
    x = wemb[tokens] + wpos[None, :, :]
    return x.reshape(mb * s, wemb.shape[1])


def embed_bwd(tokens, dx):
    """Scatter-add gradient into the embedding shards."""
    mb, s = tokens.shape
    hr = dx.shape[1]
    dx3 = dx.reshape(mb, s, hr)
    dwpos = jnp.sum(dx3, axis=0)
    return dx3, dwpos


def embed_bwd_table(tokens, dx, vocab: int):
    """d(wemb): scatter-add over token ids. Separate entry because the
    output shape depends on the (static) vocab size."""
    mb, s = tokens.shape
    hr = dx.shape[1]
    flat = dx.reshape(mb * s, hr)
    dwemb = jnp.zeros((vocab, hr), flat.dtype).at[tokens.reshape(-1)].add(flat)
    return dwemb


def mm_fwd(x, w, backend="pallas"):
    """Local partial of Algorithm 1 line 6: X_i @ W_ij (AR done by Rust)."""
    return matmul_fn(backend)(x, w)


def mm_dx(dy, w, backend="pallas"):
    """Local partial of Algorithm 1 line 13: dY_j @ W_ij^T."""
    return matmul_fn(backend)(dy, w.T)


def mm_dw(x, dy, backend="pallas"):
    """Algorithm 1 line 14 (fully local): X_i^T @ dY_j."""
    return matmul_fn(backend)(x.T, dy)


def bias_act_fwd(y, bias, act: str):
    """Post-all-reduce epilogue: add the (sharded) bias, apply activation."""
    out = y + bias[None, :]
    if act == "gelu":
        out = kref.gelu(out)
    elif act != "none":
        raise ValueError(act)
    return out


def bias_act_bwd(y, bias, dz, act: str):
    """d(pre-bias y) and d(bias) for the epilogue above."""
    if act == "gelu":
        _, vjp = jax.vjp(lambda t: kref.gelu(t + bias[None, :]), y)
        dy = vjp(dz)[0]
    elif act == "none":
        dy = dz
    else:
        raise ValueError(act)
    dbias = jnp.sum(dy, axis=0)
    return dy, dbias


def attn_fwd(qkv, *, mb: int, seq: int, heads_local: int, head_dim: int):
    """Causal multi-head attention over this GPU's local heads.

    qkv: (mb*seq, heads_local*3*head_dim), laid out head-major so a vocab
    column shard owns whole heads: per head [q | k | v].
    """
    hl, dh = heads_local, head_dim
    x = qkv.reshape(mb, seq, hl, 3 * dh)
    q, k, v = x[..., :dh], x[..., dh:2 * dh], x[..., 2 * dh:]
    scale = 1.0 / math.sqrt(dh)
    scores = jnp.einsum("bshd,bthd->bhst", q, k) * scale
    causal = jnp.tril(jnp.ones((seq, seq), bool))
    scores = jnp.where(causal[None, None, :, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhst,bthd->bshd", probs, v)
    return out.reshape(mb * seq, hl * dh)


def attn_bwd(qkv, dout, *, mb: int, seq: int, heads_local: int, head_dim: int):
    """VJP of attn_fwd with in-segment recompute (activation checkpointing:
    only qkv is cached across the fwd/bwd boundary, as in the paper)."""
    f = functools.partial(
        attn_fwd, mb=mb, seq=seq, heads_local=heads_local, head_dim=head_dim
    )
    _, vjp = jax.vjp(f, qkv)
    return vjp(dout)[0]


def ln_stats(x):
    return ln_kernel.ln_partials(x)


def ln_apply(x, stats, gamma, beta, *, total_h: int):
    return ln_kernel.ln_apply(x, stats, gamma, beta, total_h=total_h)


def _ln_xhat(x, stats, total_h: float, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mean = stats[:, 0] / total_h
    var = stats[:, 1] / total_h - mean * mean
    rstd = jax.lax.rsqrt(var + eps)
    return (xf - mean[:, None]) * rstd[:, None], rstd


def ln_bwd_stats(x, stats, gamma, dy, *, total_h: int):
    """Local partial sums for the LN backward: per row [sum(dy*g),
    sum(dy*g*xhat)] over the local hidden shard (m, 2).  Rust all-reduces
    this over the column communicator."""
    xhat, _ = _ln_xhat(x, stats, float(total_h))
    dyg = dy.astype(jnp.float32) * gamma.astype(jnp.float32)[None, :]
    return jnp.stack([jnp.sum(dyg, axis=1), jnp.sum(dyg * xhat, axis=1)], axis=1)


def ln_bwd_finish(x, stats, gamma, dy, bstats, *, total_h: int):
    """dx, dgamma, dbeta given globally reduced backward stats."""
    xhat, rstd = _ln_xhat(x, stats, float(total_h))
    dyg = dy.astype(jnp.float32) * gamma.astype(jnp.float32)[None, :]
    h = float(total_h)
    mean_dyg = bstats[:, 0] / h
    mean_dyg_xhat = bstats[:, 1] / h
    dx = rstd[:, None] * (dyg - mean_dyg[:, None] - xhat * mean_dyg_xhat[:, None])
    dgamma = jnp.sum(dy.astype(jnp.float32) * xhat, axis=0)
    dbeta = jnp.sum(dy.astype(jnp.float32), axis=0)
    return dx.astype(x.dtype), dgamma, dbeta


def xent_rowmax(logits):
    return sx_kernel.xent_rowmax(logits)


def xent_sumexp(logits, gmax):
    return sx_kernel.xent_sumexp(logits, gmax)


def xent_loss_grad(logits, labels, gmax, gsum, vocab_offset, *, total_rows: int):
    return sx_kernel.xent_loss_grad(
        logits, labels, gmax, gsum, vocab_offset, total_rows
    )


def adamw_update(w, g, m, v, t, lr, beta1, beta2, eps, weight_decay):
    """One fused AdamW step over a parameter shard (all scalars are runtime
    inputs so one artifact serves the whole schedule)."""
    m2 = beta1 * m + (1.0 - beta1) * g
    v2 = beta2 * v + (1.0 - beta2) * g * g
    mhat = m2 / (1.0 - beta1**t)
    vhat = v2 / (1.0 - beta2**t)
    w2 = w - lr * (mhat / (jnp.sqrt(vhat) + eps) + weight_decay * w)
    return w2, m2, v2


def grad_sq_sum(g):
    """Sum of squares of a gradient shard — local term of the global grad
    norm (clip decision is made by the coordinator after an all-reduce)."""
    gf = g.astype(jnp.float32)
    return jnp.sum(gf * gf).reshape(1)


def scale_buf(g, scale):
    """g * scale — used for gradient clipping and data-parallel averaging."""
    return g * scale


# --------------------------------------------------------------------------
# Whole-model serial reference (used by python tests to validate the
# segment decomposition end-to-end before Rust ever runs it)
# --------------------------------------------------------------------------


def init_params(cfg: ModelConfig, seed: int = 0) -> Dict[str, jax.Array]:
    """Deterministic full (unsharded) parameter set (python tests only).

    The Rust trainer has its own deterministic initializer
    (rust/src/layout/init.rs); serial-vs-parallel equivalence runs both
    configurations inside Rust from the same seed, so the two language
    sides never need to agree on an RNG stream.
    """
    import numpy as np

    h, f, v, s = cfg.hidden, cfg.ffn, cfg.vocab, cfg.seq
    scale = 0.02

    def norm(rng, shape):
        return jnp.asarray(rng.standard_normal(shape, dtype=np.float32) * scale)

    rng = np.random.default_rng(seed)
    p = {
        "wemb": norm(rng, (v, h)),
        "wpos": norm(rng, (s, h)),
        "head_w": norm(rng, (h, v)),
        "head_b": jnp.zeros((v,), jnp.float32),
        "lnf_g": jnp.ones((h,), jnp.float32),
        "lnf_b": jnp.zeros((h,), jnp.float32),
    }
    for l in range(cfg.layers):
        p[f"b{l}.ln1_g"] = jnp.ones((h,), jnp.float32)
        p[f"b{l}.ln1_b"] = jnp.zeros((h,), jnp.float32)
        p[f"b{l}.wqkv"] = norm(rng, (h, 3 * h))
        p[f"b{l}.bqkv"] = jnp.zeros((3 * h,), jnp.float32)
        p[f"b{l}.wproj"] = norm(rng, (h, h)) / math.sqrt(2 * cfg.layers)
        p[f"b{l}.bproj"] = jnp.zeros((h,), jnp.float32)
        p[f"b{l}.ln2_g"] = jnp.ones((h,), jnp.float32)
        p[f"b{l}.ln2_b"] = jnp.zeros((h,), jnp.float32)
        p[f"b{l}.wmlp1"] = norm(rng, (h, f))
        p[f"b{l}.bmlp1"] = jnp.zeros((f,), jnp.float32)
        p[f"b{l}.wmlp2"] = norm(rng, (f, h)) / math.sqrt(2 * cfg.layers)
        p[f"b{l}.bmlp2"] = jnp.zeros((h,), jnp.float32)
    return p


def qkv_head_major(w, b, heads: int, head_dim: int):
    """Permute a (h, 3h) qkv weight from [Q|K|V] to head-major
    [q0|k0|v0|q1|k1|v1|...] so that column shards own whole heads."""
    h = w.shape[0]
    wq, wk, wv = w[:, :h], w[:, h:2 * h], w[:, 2 * h:]
    bq, bk, bv = b[:h], b[h:2 * h], b[2 * h:]

    def per_head(t):
        return t.reshape(t.shape[0], heads, head_dim) if t.ndim == 2 else t.reshape(heads, head_dim)

    wq, wk, wv = per_head(wq), per_head(wk), per_head(wv)
    bq, bk, bv = per_head(bq), per_head(bk), per_head(bv)
    w2 = jnp.concatenate([wq, wk, wv], axis=2).reshape(h, 3 * h)
    b2 = jnp.concatenate([bq, bk, bv], axis=1).reshape(3 * h)
    return w2, b2


def serial_forward_backward(cfg: ModelConfig, params, tokens, labels,
                            backend="jnp"):
    """Full serial fwd+bwd assembled from the SAME segment functions with a
    1x1 grid — the oracle for the sharded execution tests and the source of
    truth for the Fig. 6 loss-equivalence run."""
    mb, s = tokens.shape
    h = cfg.hidden
    m = mb * s

    grads = {}
    x = embed_fwd(tokens, params["wemb"], params["wpos"])
    resid_in = [x]
    cache = []
    for l in range(cfg.layers):
        pre = x
        st1 = ln_stats(x)
        xn = ln_apply(x, st1, params[f"b{l}.ln1_g"], params[f"b{l}.ln1_b"], total_h=h)
        wq, bq = qkv_head_major(
            params[f"b{l}.wqkv"], params[f"b{l}.bqkv"], cfg.heads, cfg.head_dim
        )
        qkv = bias_act_fwd(mm_fwd(xn, wq, backend), bq, "none")
        att = attn_fwd(qkv, mb=mb, seq=s, heads_local=cfg.heads, head_dim=cfg.head_dim)
        proj = bias_act_fwd(
            mm_fwd(att, params[f"b{l}.wproj"], backend), params[f"b{l}.bproj"], "none"
        )
        x1 = pre + proj
        st2 = ln_stats(x1)
        x1n = ln_apply(x1, st2, params[f"b{l}.ln2_g"], params[f"b{l}.ln2_b"], total_h=h)
        u = bias_act_fwd(
            mm_fwd(x1n, params[f"b{l}.wmlp1"], backend), params[f"b{l}.bmlp1"], "gelu"
        )
        mlp = bias_act_fwd(
            mm_fwd(u, params[f"b{l}.wmlp2"], backend), params[f"b{l}.bmlp2"], "none"
        )
        x = x1 + mlp
        cache.append((pre, st1, xn, wq, bq, qkv, att, x1, st2, x1n, u))

    stf = ln_stats(x)
    xf = ln_apply(x, stf, params["lnf_g"], params["lnf_b"], total_h=h)
    logits = bias_act_fwd(mm_fwd(xf, params["head_w"], backend), params["head_b"], "none")
    gmax = xent_rowmax(logits)
    gsum = xent_sumexp(logits, gmax)
    loss_vec, dlogits = xent_loss_grad(
        logits, labels, gmax, gsum, jnp.zeros((1,), jnp.int32), total_rows=m
    )
    loss = jnp.sum(loss_vec)

    # ---- backward ----
    _, grads["head_b"] = bias_act_bwd(None, params["head_b"], dlogits, "none")
    grads["head_w"] = mm_dw(xf, dlogits, backend)
    dxf = mm_dx(dlogits, params["head_w"], backend)
    bst = ln_bwd_stats(x, stf, params["lnf_g"], dxf, total_h=h)
    dx, grads["lnf_g"], grads["lnf_b"] = ln_bwd_finish(
        x, stf, params["lnf_g"], dxf, bst, total_h=h
    )

    for l in reversed(range(cfg.layers)):
        pre, st1, xn, wq, bq, qkv, att, x1, st2, x1n, u = cache[l]
        # mlp2
        dmlp, grads[f"b{l}.bmlp2"] = bias_act_bwd(None, params[f"b{l}.bmlp2"], dx, "none")
        grads[f"b{l}.wmlp2"] = mm_dw(u, dmlp, backend)
        du_post = mm_dx(dmlp, params[f"b{l}.wmlp2"], backend)
        # gelu epilogue of mlp1: u = gelu(pre_u + b); we cached u POST-act?
        # We cached u post-activation; recompute needs pre-act — instead we
        # recompute the epilogue from x1n (checkpointing):
        pre_u = mm_fwd(x1n, params[f"b{l}.wmlp1"], backend)
        du, grads[f"b{l}.bmlp1"] = bias_act_bwd(pre_u, params[f"b{l}.bmlp1"], du_post, "gelu")
        grads[f"b{l}.wmlp1"] = mm_dw(x1n, du, backend)
        dx1n = mm_dx(du, params[f"b{l}.wmlp1"], backend)
        bst2 = ln_bwd_stats(x1, st2, params[f"b{l}.ln2_g"], dx1n, total_h=h)
        dx1, grads[f"b{l}.ln2_g"], grads[f"b{l}.ln2_b"] = ln_bwd_finish(
            x1, st2, params[f"b{l}.ln2_g"], dx1n, bst2, total_h=h
        )
        dx1 = dx1 + dx  # residual
        # proj
        dproj, grads[f"b{l}.bproj"] = bias_act_bwd(None, params[f"b{l}.bproj"], dx1, "none")
        grads[f"b{l}.wproj"] = mm_dw(att, dproj, backend)
        datt = mm_dx(dproj, params[f"b{l}.wproj"], backend)
        dqkv = attn_bwd(qkv, datt, mb=mb, seq=s, heads_local=cfg.heads, head_dim=cfg.head_dim)
        dqkv_b = jnp.sum(dqkv, axis=0)
        gwq = mm_dw(xn, dqkv, backend)
        dxn = mm_dx(dqkv, wq, backend)
        # un-permute the head-major qkv gradient back to [Q|K|V] layout
        grads[f"b{l}.wqkv"], grads[f"b{l}.bqkv"] = qkv_head_major_inv(
            gwq, dqkv_b, cfg.heads, cfg.head_dim
        )
        bst1 = ln_bwd_stats(pre, st1, params[f"b{l}.ln1_g"], dxn, total_h=h)
        dpre, grads[f"b{l}.ln1_g"], grads[f"b{l}.ln1_b"] = ln_bwd_finish(
            pre, st1, params[f"b{l}.ln1_g"], dxn, bst1, total_h=h
        )
        dx = dpre + dx1  # residual into the block input

    dx3, grads["wpos"] = embed_bwd(tokens, dx)
    grads["wemb"] = embed_bwd_table(tokens, dx, cfg.vocab)
    return loss, grads, logits


def qkv_head_major_inv(w2, b2, heads: int, head_dim: int):
    """Inverse permutation of qkv_head_major (gradients back to [Q|K|V])."""
    h = w2.shape[0]
    w3 = w2.reshape(h, heads, 3, head_dim)
    b3 = b2.reshape(heads, 3, head_dim)
    wq, wk, wv = w3[:, :, 0, :], w3[:, :, 1, :], w3[:, :, 2, :]
    bq, bk, bv = b3[:, 0, :], b3[:, 1, :], b3[:, 2, :]
    w = jnp.concatenate(
        [wq.reshape(h, -1), wk.reshape(h, -1), wv.reshape(h, -1)], axis=1
    )
    b = jnp.concatenate([bq.reshape(-1), bk.reshape(-1), bv.reshape(-1)])
    return w, b


def serial_loss_via_jax_grad(cfg: ModelConfig, params, tokens, labels):
    """Independent oracle: the same architecture written as one jax fn and
    differentiated with jax.grad — validates the hand-rolled backward."""

    def fwd(p):
        mb, s = tokens.shape
        x = embed_fwd(tokens, p["wemb"], p["wpos"])
        for l in range(cfg.layers):
            xn = kref.layernorm(x, p[f"b{l}.ln1_g"], p[f"b{l}.ln1_b"])
            wq, bq = qkv_head_major(p[f"b{l}.wqkv"], p[f"b{l}.bqkv"], cfg.heads, cfg.head_dim)
            qkv = xn @ wq + bq[None, :]
            att = attn_fwd(qkv, mb=mb, seq=s, heads_local=cfg.heads, head_dim=cfg.head_dim)
            x = x + att @ p[f"b{l}.wproj"] + p[f"b{l}.bproj"][None, :]
            xn2 = kref.layernorm(x, p[f"b{l}.ln2_g"], p[f"b{l}.ln2_b"])
            u = kref.gelu(xn2 @ p[f"b{l}.wmlp1"] + p[f"b{l}.bmlp1"][None, :])
            x = x + u @ p[f"b{l}.wmlp2"] + p[f"b{l}.bmlp2"][None, :]
        xf = kref.layernorm(x, p["lnf_g"], p["lnf_b"])
        logits = xf @ p["head_w"] + p["head_b"][None, :]
        lf = logits.astype(jnp.float32)
        logz = jax.scipy.special.logsumexp(lf, axis=1)
        picked = jnp.take_along_axis(lf, labels.reshape(-1)[:, None], axis=1)[:, 0]
        return jnp.mean(logz - picked)

    return jax.value_and_grad(fwd)(params)
