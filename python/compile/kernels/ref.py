"""Pure-jnp oracles for every Pallas kernel — the CORE correctness signal.

pytest (python/tests/test_kernels.py) sweeps shapes/dtypes with hypothesis
and asserts allclose between each kernel and its oracle here.  Nothing in
this module uses Pallas.
"""

import jax
import jax.numpy as jnp


def matmul(a, b, out_dtype=None):
    if out_dtype is None:
        out_dtype = jnp.promote_types(a.dtype, b.dtype)
    return jnp.dot(
        a, b, preferred_element_type=jnp.float32
    ).astype(out_dtype)


def gelu(x):
    c = jnp.sqrt(2.0 / jnp.pi).astype(x.dtype)
    return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x**3)))


def fused_linear(a, b, bias, act="none"):
    y = jnp.dot(a, b, preferred_element_type=jnp.float32) + bias.astype(jnp.float32)
    if act == "relu":
        y = jnp.maximum(y, 0.0)
    elif act == "gelu":
        y = gelu(y)
    elif act != "none":
        raise ValueError(act)
    return y.astype(jnp.promote_types(a.dtype, b.dtype))


def layernorm(x, gamma, beta, eps=1e-5):
    xf = x.astype(jnp.float32)
    mean = jnp.mean(xf, axis=1, keepdims=True)
    var = jnp.mean((xf - mean) ** 2, axis=1, keepdims=True)
    xhat = (xf - mean) * jax.lax.rsqrt(var + eps)
    return (xhat * gamma.astype(jnp.float32) + beta.astype(jnp.float32)).astype(x.dtype)


def softmax_xent(logits, labels):
    """Mean NLL and gradient wrt logits."""
    m = logits.shape[0]
    lf = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(lf, axis=1)
    picked = jnp.take_along_axis(lf, labels[:, None], axis=1)[:, 0]
    loss = jnp.mean(logz - picked)
    softmax = jnp.exp(lf - logz[:, None])
    onehot = jax.nn.one_hot(labels, logits.shape[1], dtype=jnp.float32)
    dlogits = ((softmax - onehot) / m).astype(logits.dtype)
    return loss, dlogits
