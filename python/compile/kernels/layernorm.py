"""Row-wise LayerNorm Pallas kernel.

Two entry points matching the sharded-LN protocol of the Rust coordinator
(DESIGN.md: activations are column-sharded across the grid, so the mean and
variance over the full hidden dimension need a 2-float-per-row all-reduce
that Rust performs between these two kernels):

  ln_partials(x)           -> (rows, 2) partial [sum, sum-of-squares]
  ln_apply(x, stats, g, b) -> normalized rows given *global* stats

``layernorm`` composes the two for the unsharded (serial / oracle-vs-kernel
test) case.  The kernel tiles rows into VMEM-sized blocks; the hidden dim
of one row block always fits (H <= a few K for our configs), so each grid
step is one HBM pass.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .matmul import pick_block


def _row_block(rows: int, cols: int) -> int:
    """Pick a row-block size so a (br, cols) f32 tile is <= ~2 MiB."""
    target = max(1, (2 * 1024 * 1024) // (4 * max(cols, 1)))
    return pick_block(rows, min(rows, target))


def _partials_kernel(x_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    s = jnp.sum(x, axis=1)
    ss = jnp.sum(x * x, axis=1)
    o_ref[...] = jnp.stack([s, ss], axis=1)


@jax.jit
def ln_partials(x: jax.Array) -> jax.Array:
    """Per-row [sum, sum_sq] over the *local* hidden shard: (m, h) -> (m, 2)."""
    m, h = x.shape
    br = _row_block(m, h)
    return pl.pallas_call(
        _partials_kernel,
        grid=(m // br,),
        in_specs=[pl.BlockSpec((br, h), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, 2), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, 2), jnp.float32),
        interpret=True,
    )(x)


def _apply_kernel(x_ref, stats_ref, g_ref, b_ref, o_ref, *, total_h, eps):
    x = x_ref[...].astype(jnp.float32)
    s = stats_ref[..., 0]
    ss = stats_ref[..., 1]
    mean = s / total_h
    var = ss / total_h - mean * mean
    rstd = jax.lax.rsqrt(var + eps)
    xhat = (x - mean[:, None]) * rstd[:, None]
    o_ref[...] = (xhat * g_ref[...].astype(jnp.float32) + b_ref[...].astype(jnp.float32)).astype(
        o_ref.dtype
    )


@functools.partial(jax.jit, static_argnames=("total_h", "eps"))
def ln_apply(x: jax.Array, stats: jax.Array, gamma: jax.Array, beta: jax.Array,
             total_h: int, eps: float = 1e-5) -> jax.Array:
    """Normalize local shard ``x`` (m, h_local) with global stats (m, 2).

    ``total_h`` is the full (unsharded) hidden width the stats were reduced
    over; gamma/beta are the local shard's slices (h_local,).
    """
    m, h = x.shape
    br = _row_block(m, h)
    kernel = functools.partial(_apply_kernel, total_h=float(total_h), eps=eps)
    return pl.pallas_call(
        kernel,
        grid=(m // br,),
        in_specs=[
            pl.BlockSpec((br, h), lambda i: (i, 0)),
            pl.BlockSpec((br, 2), lambda i: (i, 0)),
            pl.BlockSpec((1, h), lambda i: (0, 0)),
            pl.BlockSpec((1, h), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((br, h), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, h), x.dtype),
        interpret=True,
    )(x, stats, gamma.reshape(1, h), beta.reshape(1, h))


def layernorm(x: jax.Array, gamma: jax.Array, beta: jax.Array, eps: float = 1e-5):
    """Unsharded layernorm = partials + apply with h_local == total_h."""
    stats = ln_partials(x)
    return ln_apply(x, stats, gamma, beta, total_h=x.shape[1], eps=eps)
