"""Fused linear kernel: ``act(A @ B + bias)`` in one VMEM pass.

The paper's MLP block computes ``GELU(X_i W_ij)`` per GPU; fusing the bias
add and activation into the epilogue of the blocked matmul avoids a second
HBM round-trip over the (m, n) output — on a TPU this is the difference
between streaming the activation tile out of VMEM once vs. three times.

Bias is laid out per output-column shard (n/Gc wide), matching the 2-D
weight decomposition of Algorithm 1: the bias of column-block j lives with
``W_ij`` and is applied after the column all-reduce completes — so the
fused epilogue here is used on the *reduced* operand path (serial mode,
Gr == 1) and on the per-shard pre-activation path where the activation is
deferred (``act='none'``).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .matmul import pick_blocks, _vmem_scratch

ACTIVATIONS = ("none", "relu", "gelu")


def _apply_act(x, act: str):
    if act == "none":
        return x
    if act == "relu":
        return jnp.maximum(x, 0.0)
    if act == "gelu":
        # tanh-approximation GELU, matching the reference oracle.
        c = jnp.sqrt(2.0 / jnp.pi).astype(x.dtype)
        return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x**3)))
    raise ValueError(f"unknown activation {act!r}")


def _fused_kernel(a_ref, b_ref, bias_ref, o_ref, acc_ref, *, k_steps, act):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _epilogue():
        out = acc_ref[...] + bias_ref[...].astype(jnp.float32)
        o_ref[...] = _apply_act(out, act).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("act",))
def fused_linear(a: jax.Array, b: jax.Array, bias: jax.Array, act: str = "none"):
    """act(A @ B + bias): A (m,k), B (k,n), bias (n,) -> (m,n)."""
    if act not in ACTIVATIONS:
        raise ValueError(f"act must be one of {ACTIVATIONS}, got {act!r}")
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    assert bias.shape == (n,), bias.shape
    out_dtype = jnp.promote_types(a.dtype, b.dtype)
    bm, bk, bn = pick_blocks(m, k, n)
    k_steps = k // bk

    kernel = functools.partial(_fused_kernel, k_steps=k_steps, act=act)
    # bias enters as (1, n) so BlockSpec can tile its columns alongside the
    # output tile.
    bias2d = bias.reshape(1, n)
    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn, k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[_vmem_scratch((bm, bn))],
        interpret=True,
    )(a, b, bias2d)
