"""Fused softmax cross-entropy Pallas kernel, vocab-shard aware.

The output head of the live GPT is a normal Algorithm-1 FC layer, so the
logits arrive *column-sharded over the vocabulary* (each GPU in a grid row
holds a contiguous (m, V/Gc) slice).  Computing softmax cross-entropy then
needs two tiny row-wise reductions across the row communicator (max, then
sum-exp) — the Rust coordinator performs those between these kernels:

  xent_rowmax(logits)                       -> (m,) local row max
  xent_sumexp(logits, gmax)                 -> (m,) local sum exp(l - gmax)
  xent_loss_grad(logits, labels, gmax, gsum, vocab_offset)
        -> per-row loss contribution (m,) and dlogits (m, v_local)

With Gc == 1 these compose into the serial fused softmax-xent, which is
what the oracle test checks.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .layernorm import _row_block

NEG_INF = -1e30


def _rowmax_kernel(x_ref, o_ref):
    o_ref[...] = jnp.max(x_ref[...].astype(jnp.float32), axis=1)


@jax.jit
def xent_rowmax(logits: jax.Array) -> jax.Array:
    m, v = logits.shape
    br = _row_block(m, v)
    return pl.pallas_call(
        _rowmax_kernel,
        grid=(m // br,),
        in_specs=[pl.BlockSpec((br, v), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((m,), jnp.float32),
        interpret=True,
    )(logits)


def _sumexp_kernel(x_ref, gmax_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    o_ref[...] = jnp.sum(jnp.exp(x - gmax_ref[...][:, None]), axis=1)


@jax.jit
def xent_sumexp(logits: jax.Array, gmax: jax.Array) -> jax.Array:
    m, v = logits.shape
    br = _row_block(m, v)
    return pl.pallas_call(
        _sumexp_kernel,
        grid=(m // br,),
        in_specs=[
            pl.BlockSpec((br, v), lambda i: (i, 0)),
            pl.BlockSpec((br,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((br,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((m,), jnp.float32),
        interpret=True,
    )(logits, gmax)


def _loss_grad_kernel(x_ref, lab_ref, gmax_ref, gsum_ref, off_ref,
                      loss_ref, dx_ref, *, v_local, inv_m):
    x = x_ref[...].astype(jnp.float32)
    gmax = gmax_ref[...]
    gsum = gsum_ref[...]
    # column ids of this vocab shard, as global vocab ids
    cols = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1) + off_ref[0]
    onehot = (cols == lab_ref[...][:, None]).astype(jnp.float32)
    logz = jnp.log(gsum) + gmax
    # local picked-logit term: non-zero only on the shard owning the label
    picked = jnp.sum(x * onehot, axis=1)
    owned = jnp.sum(onehot, axis=1)  # 1.0 iff label lives in this shard
    # per-row local contribution: the logz term is weighted by ownership so
    # that summing contributions across the row communicator (Rust-side
    # all-reduce) yields logz - picked exactly once per row.
    loss_ref[...] = (owned * logz - picked) * inv_m
    softmax = jnp.exp(x - gmax[:, None]) / gsum[:, None]
    dx_ref[...] = ((softmax - onehot) * inv_m).astype(dx_ref.dtype)


@functools.partial(jax.jit, static_argnames=("total_rows",))
def xent_loss_grad(logits: jax.Array, labels: jax.Array, gmax: jax.Array,
                   gsum: jax.Array, vocab_offset: jax.Array, total_rows: int):
    """Per-row local loss contribution and d(logits)/d(mean loss).

    total_rows is the *global* number of rows the mean is taken over
    (= B*S of the full batch), so gradients from different data-parallel
    groups sum to the true mean gradient.
    Summing loss across the row communicator AND across rows yields the
    global mean NLL.
    """
    m, v = logits.shape
    br = _row_block(m, v)
    kernel = functools.partial(
        _loss_grad_kernel, v_local=v, inv_m=1.0 / float(total_rows)
    )
    return pl.pallas_call(
        kernel,
        grid=(m // br,),
        in_specs=[
            pl.BlockSpec((br, v), lambda i: (i, 0)),
            pl.BlockSpec((br,), lambda i: (i,)),
            pl.BlockSpec((br,), lambda i: (i,)),
            pl.BlockSpec((br,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((br,), lambda i: (i,)),
            pl.BlockSpec((br, v), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m,), jnp.float32),
            jax.ShapeDtypeStruct((m, v), logits.dtype),
        ],
        interpret=True,
    )(logits, labels, gmax, gsum, vocab_offset)


def softmax_xent(logits: jax.Array, labels: jax.Array):
    """Serial fused softmax cross-entropy (mean NLL) + grad — Gc == 1 path."""
    m, _ = logits.shape
    gmax = xent_rowmax(logits)
    gsum = xent_sumexp(logits, gmax)
    off = jnp.zeros((1,), jnp.int32)
    loss_vec, dlogits = xent_loss_grad(logits, labels, gmax, gsum, off, m)
    return jnp.sum(loss_vec), dlogits
