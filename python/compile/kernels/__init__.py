"""Layer-1 Pallas kernels for Tensor3D.

Each kernel is the per-GPU *local* hot spot of Algorithm 1 (the shard GEMM
and its fusions).  Kernels are written in TPU idiom -- BlockSpec tiling for
VMEM, MXU-aligned 128-multiple tiles where shapes allow -- and are lowered
with ``interpret=True`` so the emitted HLO runs on the CPU PJRT client that
the Rust coordinator drives (real-TPU lowering emits a Mosaic custom call
the CPU plugin cannot execute; see DESIGN.md section Hardware-Adaptation).

Public surface:
  matmul.matmul             -- blocked C = A @ B
  fused_linear.fused_linear -- act(A @ B + bias)
  layernorm.layernorm       -- row-wise layer normalization
  softmax_xent.softmax_xent -- fused log-softmax + NLL (vocab-sharded aware)
  ref                       -- pure-jnp oracles used by pytest
"""
from . import matmul, fused_linear, layernorm, softmax_xent, ref  # noqa: F401
