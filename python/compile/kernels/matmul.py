"""Blocked matrix-multiply Pallas kernel — the shard-GEMM hot spot.

This is the per-GPU local computation of Algorithm 1 in the paper
(``X_i @ W_ij`` in the forward pass, ``dY_j @ W_ij^T`` and ``X_i^T @ dY_j``
in the backward pass — the transposed variants are expressed by passing
pre-transposed operands so a single kernel serves all three).

TPU mapping (DESIGN.md §Hardware-Adaptation):
  * the CUDA threadblock tiling of the paper's GPU kernels becomes a 3-D
    Pallas ``grid`` of ``(m/bm, n/bn, k/bk)`` with ``BlockSpec`` index maps;
  * tiles live in VMEM (the TPU scratchpad); block sizes are chosen so
    ``(bm*bk + bk*bn + bm*bn) * 4B`` stays well under the ~16 MiB VMEM
    budget, leaving headroom for double buffering;
  * the inner dimension iterates fastest so the f32 accumulator tile is
    reused across the k-loop and only written back once — this is the MXU
    (128x128 systolic array) friendly schedule, with tile edges padded to
    multiples of the 8x128 vreg layout where shapes allow.

Run with ``interpret=True`` everywhere: the lowered HLO is plain XLA ops
that the CPU PJRT client (Rust side) executes.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# VMEM budget we tile for (bytes).  Real TPUs have ~16 MiB of VMEM per
# core; we target half of it so the compiler has room to double-buffer the
# HBM->VMEM streams for the A and B tiles.
VMEM_BUDGET = 8 * 1024 * 1024

# MXU systolic array edge; tiles snap to multiples of this when possible.
MXU_EDGE = 128
# f32 vector register sublane size: min sensible tile in the row dim.
SUBLANE = 8


def _divisors_desc(n: int, cap: int) -> list:
    """Divisors of ``n`` that are <= cap, descending."""
    out = [d for d in range(1, min(n, cap) + 1) if n % d == 0]
    out.sort(reverse=True)
    return out


def pick_block(dim: int, target: int) -> int:
    """Largest divisor of ``dim`` that is <= target, preferring multiples
    of MXU_EDGE, then of SUBLANE, then anything."""
    divs = _divisors_desc(dim, target)
    for d in divs:
        if d % MXU_EDGE == 0:
            return d
    for d in divs:
        if d % SUBLANE == 0:
            return d
    return divs[0] if divs else dim


def pick_blocks(m: int, k: int, n: int):
    """Choose (bm, bk, bn) fitting the VMEM budget.

    Strategy: start from MXU-friendly 256x256x256 and shrink to divisors.
    The A-tile (bm x bk), B-tile (bk x bn) and f32 accumulator (bm x bn)
    must fit VMEM_BUDGET together.
    """
    bm = pick_block(m, 256)
    bn = pick_block(n, 256)
    bk = pick_block(k, 256)

    def footprint(bm, bk, bn):
        return 4 * (bm * bk + bk * bn + bm * bn)

    # Shrink the largest tile edge until we fit.
    while footprint(bm, bk, bn) > VMEM_BUDGET:
        if bk >= bm and bk >= bn and bk > 1:
            bk = pick_block(k, bk // 2)
        elif bm >= bn and bm > 1:
            bm = pick_block(m, bm // 2)
        elif bn > 1:
            bn = pick_block(n, bn // 2)
        else:  # pragma: no cover - degenerate shapes always fit
            break
    return bm, bk, bn


def vmem_bytes(m: int, k: int, n: int) -> int:
    """VMEM footprint (bytes) of the chosen tiling — used by the §Perf
    analysis in DESIGN.md / EXPERIMENTS.md."""
    bm, bk, bn = pick_blocks(m, k, n)
    return 4 * (bm * bk + bk * bn + bm * bn)


def mxu_utilization_estimate(m: int, k: int, n: int) -> float:
    """Fraction of MXU lanes a (bm, bk, bn) tiling keeps busy.

    A tile edge that is not a multiple of 128 wastes the remainder lanes of
    the systolic array on its last pass; this returns the utilization of
    the steady state, i.e. prod(edge / ceil128(edge) rounded up).
    """
    bm, bk, bn = pick_blocks(m, k, n)

    def eff(e):
        pad = -e % MXU_EDGE
        return e / (e + pad) if e + pad else 1.0

    return eff(bm) * eff(bk) * eff(bn)


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, k_steps: int):
    """Grid = (m/bm, n/bn, k/bk); k innermost; f32 accumulator in VMEM."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("out_dtype",))
def matmul(a: jax.Array, b: jax.Array, out_dtype=None) -> jax.Array:
    """C = A @ B via the blocked Pallas kernel.

    A: (m, k), B: (k, n) -> C: (m, n).  Accumulation is always f32
    (``preferred_element_type``), output cast to ``out_dtype`` (defaults to
    the promoted input dtype) — this mirrors the paper's mixed-precision
    setup where bf16 operands accumulate in f32 on the MXU.
    """
    m, k = a.shape
    k2, n = b.shape
    if k != k2:
        raise ValueError(f"matmul inner dims mismatch: {a.shape} @ {b.shape}")
    if out_dtype is None:
        out_dtype = jnp.promote_types(a.dtype, b.dtype)
    bm, bk, bn = pick_blocks(m, k, n)
    k_steps = k // bk

    kernel = functools.partial(_matmul_kernel, k_steps=k_steps)
    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn, k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pl.pltpu.VMEM((bm, bn), jnp.float32)]
        if hasattr(pl, "pltpu")
        else [_vmem_scratch((bm, bn))],
        interpret=True,
    )(a, b)


def _vmem_scratch(shape):
    """VMEM scratch allocation, tolerant of pallas API layout differences."""
    try:
        from jax.experimental.pallas import tpu as pltpu

        return pltpu.VMEM(shape, jnp.float32)
    except Exception:  # pragma: no cover - interpret mode fallback
        return pl.MemoryRef(shape, jnp.float32)


def matmul_at(a: jax.Array, b: jax.Array) -> jax.Array:
    """C = A^T @ B — the dW = X^T dY step of Algorithm 1 (line 14)."""
    return matmul(a.T, b)


def matmul_bt(a: jax.Array, b: jax.Array) -> jax.Array:
    """C = A @ B^T — the dX = dY W^T step of Algorithm 1 (line 13)."""
    return matmul(a, b.T)
