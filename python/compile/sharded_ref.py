"""Executable specification of the Rust coordinator's sharded protocol.

This module emulates, in pure Python over in-process "GPUs", exactly the
sequence of local segment executions and collectives that the Rust
coordinator (rust/src/coordinator/) performs for one training step of the
live GPT under Algorithm 1 + §4.1 (transposed alternate layers).  It is the
source of truth for:

  * how every parameter is sharded onto GPU(i, j) of a G_r x G_c grid
    (``shard_params``), including the §4.1 transposed layout for the
    attention out-projection and second MLP matmul;
  * which communicator (row / column) each all-reduce uses, and in which
    order (``grid_forward_backward``);
  * ownership flags used for gradient-norm accounting (replicated shards
    are counted exactly once).

python/tests/test_sharded.py asserts that assembling the sharded gradients
reproduces the serial reference for every grid that divides gpt-nano, which
pins the protocol before Rust ever executes it.  The Rust implementation
mirrors this file collective-for-collective; keep them in sync.

Communicator naming follows the paper: GPUs sharing a grid *column*
(same j, varying i) form the column communicator (All-Reduce_c, used by
the forward pass of non-transposed layers); GPUs sharing a grid *row*
(same i, varying j) form the row communicator (All-Reduce_r).
"""

import dataclasses
from typing import Dict, List, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from . import model as M


@dataclasses.dataclass
class Shard:
    """A parameter shard on one GPU: array + ownership for norm accounting."""

    array: jax.Array
    owned: bool  # True iff this GPU is the canonical owner of the values


def _slice(t, dim: int, idx: int, parts: int):
    n = t.shape[dim] // parts
    sl = [slice(None)] * t.ndim
    sl[dim] = slice(idx * n, (idx + 1) * n)
    return t[tuple(sl)]


def shard_params(cfg: M.ModelConfig, params, g_r: int, g_c: int
                 ) -> List[List[Dict[str, Shard]]]:
    """Distribute full params onto the grid. Returns grid[i][j] -> shards.

    Layout rules (mirrored by rust/src/layout/):
      * activation-dim (hidden) vectors — LN params, wemb/wpos columns,
        row-sharded biases — are sliced over the r-index, replicated over
        columns; owner is j == 0.
      * column-sharded biases (bqkv, bmlp1, head_b) are sliced over the
        c-index, replicated over rows; owner is i == 0.
      * non-transposed weights W (k, n) -> block (i, j) of (k/G_r, n/G_c).
      * §4.1 transposed weights W (k, n) -> block (j, i) of (k/G_c, n/G_r):
        the *input* dim is sharded over columns because the producing
        layer's output was column-sharded over the c-index.
      * every weight block is unique, hence always owned.
    """
    grid = [[{} for _ in range(g_c)] for _ in range(g_r)]
    h = cfg.hidden

    def put(name, fn_ij, owned_fn):
        for i in range(g_r):
            for j in range(g_c):
                grid[i][j][name] = Shard(fn_ij(i, j), owned_fn(i, j))

    own_j0 = lambda i, j: j == 0
    own_i0 = lambda i, j: i == 0
    own_all = lambda i, j: True

    put("wemb", lambda i, j: _slice(params["wemb"], 1, i, g_r), own_j0)
    put("wpos", lambda i, j: _slice(params["wpos"], 1, i, g_r), own_j0)
    put("lnf_g", lambda i, j: _slice(params["lnf_g"], 0, i, g_r), own_j0)
    put("lnf_b", lambda i, j: _slice(params["lnf_b"], 0, i, g_r), own_j0)
    # head: plain Algorithm-1 FC (non-transposed)
    put(
        "head_w",
        lambda i, j: _slice(_slice(params["head_w"], 0, i, g_r), 1, j, g_c),
        own_all,
    )
    put("head_b", lambda i, j: _slice(params["head_b"], 0, j, g_c), own_i0)

    for l in range(cfg.layers):
        wq, bq = M.qkv_head_major(
            params[f"b{l}.wqkv"], params[f"b{l}.bqkv"], cfg.heads, cfg.head_dim
        )
        put(f"b{l}.ln1_g", lambda i, j, l=l: _slice(params[f"b{l}.ln1_g"], 0, i, g_r), own_j0)
        put(f"b{l}.ln1_b", lambda i, j, l=l: _slice(params[f"b{l}.ln1_b"], 0, i, g_r), own_j0)
        put(
            f"b{l}.wqkv",
            lambda i, j, wq=wq: _slice(_slice(wq, 0, i, g_r), 1, j, g_c),
            own_all,
        )
        put(f"b{l}.bqkv", lambda i, j, bq=bq: _slice(bq, 0, j, g_c), own_i0)
        # §4.1 transposed: block (j, i), input dim sharded over c-index
        put(
            f"b{l}.wproj",
            lambda i, j, l=l: _slice(_slice(params[f"b{l}.wproj"], 0, j, g_c), 1, i, g_r),
            own_all,
        )
        put(f"b{l}.bproj", lambda i, j, l=l: _slice(params[f"b{l}.bproj"], 0, i, g_r), own_j0)
        put(f"b{l}.ln2_g", lambda i, j, l=l: _slice(params[f"b{l}.ln2_g"], 0, i, g_r), own_j0)
        put(f"b{l}.ln2_b", lambda i, j, l=l: _slice(params[f"b{l}.ln2_b"], 0, i, g_r), own_j0)
        put(
            f"b{l}.wmlp1",
            lambda i, j, l=l: _slice(_slice(params[f"b{l}.wmlp1"], 0, i, g_r), 1, j, g_c),
            own_all,
        )
        put(f"b{l}.bmlp1", lambda i, j, l=l: _slice(params[f"b{l}.bmlp1"], 0, j, g_c), own_i0)
        put(
            f"b{l}.wmlp2",
            lambda i, j, l=l: _slice(_slice(params[f"b{l}.wmlp2"], 0, j, g_c), 1, i, g_r),
            own_all,
        )
        put(f"b{l}.bmlp2", lambda i, j, l=l: _slice(params[f"b{l}.bmlp2"], 0, i, g_r), own_j0)
    return grid


def assemble_grads(cfg: M.ModelConfig, grad_grid, g_r: int, g_c: int):
    """Reassemble full gradients from the per-GPU shard grids (the inverse
    of shard_params; includes the qkv head-major inverse permutation)."""
    out = {}

    def cat_r(name):  # row-sharded vectors / matrices along last dim
        return jnp.concatenate([grad_grid[i][0][name] for i in range(g_r)], axis=-1)

    def cat_c(name):
        return jnp.concatenate([grad_grid[0][j][name] for j in range(g_c)], axis=-1)

    def blocks(name, transposed=False):
        if transposed:
            rows = [
                jnp.concatenate([grad_grid[i][j][name] for i in range(g_r)], axis=1)
                for j in range(g_c)
            ]
            return jnp.concatenate(rows, axis=0)
        rows = [
            jnp.concatenate([grad_grid[i][j][name] for j in range(g_c)], axis=1)
            for i in range(g_r)
        ]
        return jnp.concatenate(rows, axis=0)

    out["wemb"] = cat_r("wemb")
    out["wpos"] = cat_r("wpos")
    out["lnf_g"] = cat_r("lnf_g")
    out["lnf_b"] = cat_r("lnf_b")
    out["head_w"] = blocks("head_w")
    out["head_b"] = cat_c("head_b")
    for l in range(cfg.layers):
        wq = blocks(f"b{l}.wqkv")
        bq = cat_c(f"b{l}.bqkv")
        out[f"b{l}.wqkv"], out[f"b{l}.bqkv"] = M.qkv_head_major_inv(
            wq, bq, cfg.heads, cfg.head_dim
        )
        out[f"b{l}.ln1_g"] = cat_r(f"b{l}.ln1_g")
        out[f"b{l}.ln1_b"] = cat_r(f"b{l}.ln1_b")
        out[f"b{l}.wproj"] = blocks(f"b{l}.wproj", transposed=True)
        out[f"b{l}.bproj"] = cat_r(f"b{l}.bproj")
        out[f"b{l}.ln2_g"] = cat_r(f"b{l}.ln2_g")
        out[f"b{l}.ln2_b"] = cat_r(f"b{l}.ln2_b")
        out[f"b{l}.wmlp1"] = blocks(f"b{l}.wmlp1")
        out[f"b{l}.bmlp1"] = cat_c(f"b{l}.bmlp1")
        out[f"b{l}.wmlp2"] = blocks(f"b{l}.wmlp2", transposed=True)
        out[f"b{l}.bmlp2"] = cat_r(f"b{l}.bmlp2")
    return out


# -------------------------------------------------------------------------
# Collectives over the in-process grid (lists indexed [i][j])
# -------------------------------------------------------------------------


def ar_col(vals, g_r, g_c, op="sum"):
    """All-reduce over column communicators: reduce over i for fixed j."""
    out = [[None] * g_c for _ in range(g_r)]
    for j in range(g_c):
        acc = vals[0][j]
        for i in range(1, g_r):
            acc = jnp.maximum(acc, vals[i][j]) if op == "max" else acc + vals[i][j]
        for i in range(g_r):
            out[i][j] = acc
    return out


def ar_row(vals, g_r, g_c, op="sum"):
    """All-reduce over row communicators: reduce over j for fixed i."""
    out = [[None] * g_c for _ in range(g_r)]
    for i in range(g_r):
        acc = vals[i][0]
        for j in range(1, g_c):
            acc = jnp.maximum(acc, vals[i][j]) if op == "max" else acc + vals[i][j]
        for j in range(g_c):
            out[i][j] = acc
    return out


def _each(g_r, g_c, fn):
    return [[fn(i, j) for j in range(g_c)] for i in range(g_r)]


# -------------------------------------------------------------------------
# One forward+backward over the grid — the coordinator's step, verbatim
# -------------------------------------------------------------------------


def grid_forward_backward(cfg: M.ModelConfig, grid, tokens, labels,
                          g_r: int, g_c: int, total_rows: int = None,
                          backend: str = "jnp"):
    """Forward+backward of one (sub-)batch shard on a G_r x G_c grid.

    tokens: (mb, S) — identical on every GPU of the grid (the group's
    shard); labels flattened (mb*S,).  Returns (loss, grad_grid) where
    grad_grid[i][j] maps param name -> gradient shard.
    """
    mb, s = tokens.shape
    m = mb * s
    h = cfg.hidden
    if total_rows is None:
        total_rows = m
    hl = cfg.heads // g_c  # local heads per column shard
    P = lambda i, j, name: grid[i][j][name].array

    # ---------------- forward ----------------
    x = _each(g_r, g_c, lambda i, j: M.embed_fwd(tokens, P(i, j, "wemb"), P(i, j, "wpos")))
    cache = []
    for l in range(cfg.layers):
        pre = x
        st1 = ar_col(_each(g_r, g_c, lambda i, j: M.ln_stats(x[i][j])), g_r, g_c)
        xn = _each(g_r, g_c, lambda i, j: M.ln_apply(
            x[i][j], st1[i][j], P(i, j, f"b{l}.ln1_g"), P(i, j, f"b{l}.ln1_b"), total_h=h))
        # qkv: non-transposed FC -> forward AR over column comm (Alg. 1 l.6)
        qkv = ar_col(_each(g_r, g_c, lambda i, j: M.mm_fwd(
            xn[i][j], P(i, j, f"b{l}.wqkv"), backend)), g_r, g_c)
        qkv = _each(g_r, g_c, lambda i, j: qkv[i][j] + P(i, j, f"b{l}.bqkv")[None, :])
        att = _each(g_r, g_c, lambda i, j: M.attn_fwd(
            qkv[i][j], mb=mb, seq=s, heads_local=hl, head_dim=cfg.head_dim))
        # out-projection: §4.1 transposed FC -> forward AR over ROW comm
        proj = ar_row(_each(g_r, g_c, lambda i, j: M.mm_fwd(
            att[i][j], P(i, j, f"b{l}.wproj"), backend)), g_r, g_c)
        x1 = _each(g_r, g_c, lambda i, j: pre[i][j] + proj[i][j] + P(i, j, f"b{l}.bproj")[None, :])
        st2 = ar_col(_each(g_r, g_c, lambda i, j: M.ln_stats(x1[i][j])), g_r, g_c)
        x1n = _each(g_r, g_c, lambda i, j: M.ln_apply(
            x1[i][j], st2[i][j], P(i, j, f"b{l}.ln2_g"), P(i, j, f"b{l}.ln2_b"), total_h=h))
        # mlp1: non-transposed -> AR over column comm; cache PRE-activation
        upre = ar_col(_each(g_r, g_c, lambda i, j: M.mm_fwd(
            x1n[i][j], P(i, j, f"b{l}.wmlp1"), backend)), g_r, g_c)
        upre = _each(g_r, g_c, lambda i, j: upre[i][j] + P(i, j, f"b{l}.bmlp1")[None, :])
        u = _each(g_r, g_c, lambda i, j: M.bias_act_fwd(
            upre[i][j], jnp.zeros((upre[i][j].shape[1],), upre[i][j].dtype), "gelu"))
        # mlp2: transposed -> AR over ROW comm
        mlp = ar_row(_each(g_r, g_c, lambda i, j: M.mm_fwd(
            u[i][j], P(i, j, f"b{l}.wmlp2"), backend)), g_r, g_c)
        x = _each(g_r, g_c, lambda i, j: x1[i][j] + mlp[i][j] + P(i, j, f"b{l}.bmlp2")[None, :])
        cache.append((pre, st1, xn, qkv, att, x1, st2, x1n, upre, u))

    stf = ar_col(_each(g_r, g_c, lambda i, j: M.ln_stats(x[i][j])), g_r, g_c)
    xf = _each(g_r, g_c, lambda i, j: M.ln_apply(
        x[i][j], stf[i][j], P(i, j, "lnf_g"), P(i, j, "lnf_b"), total_h=h))
    logits = ar_col(_each(g_r, g_c, lambda i, j: M.mm_fwd(
        xf[i][j], P(i, j, "head_w"), backend)), g_r, g_c)
    logits = _each(g_r, g_c, lambda i, j: logits[i][j] + P(i, j, "head_b")[None, :])
    # vocab-parallel softmax-xent: two tiny ARs over the ROW comm
    gmax = ar_row(_each(g_r, g_c, lambda i, j: M.xent_rowmax(logits[i][j])), g_r, g_c, op="max")
    gsum = ar_row(_each(g_r, g_c, lambda i, j: M.xent_sumexp(logits[i][j], gmax[i][j])), g_r, g_c)
    vshard = cfg.vocab // g_c
    lg = _each(g_r, g_c, lambda i, j: M.xent_loss_grad(
        logits[i][j], labels, gmax[i][j], gsum[i][j],
        jnp.asarray(np.array([j * vshard], np.int32)), total_rows=total_rows))
    loss_part = _each(g_r, g_c, lambda i, j: jnp.sum(lg[i][j][0]))
    loss = float(sum(loss_part[0][j] for j in range(g_c)))  # row comm of rank (0, :)
    dlogits = _each(g_r, g_c, lambda i, j: lg[i][j][1])

    # ---------------- backward ----------------
    g = _each(g_r, g_c, lambda i, j: {})

    def setg(i, j, name, val):
        g[i][j][name] = val

    # head (non-transposed): bwd AR over ROW comm
    for i in range(g_r):
        for j in range(g_c):
            setg(i, j, "head_b", jnp.sum(dlogits[i][j], axis=0))
            setg(i, j, "head_w", M.mm_dw(xf[i][j], dlogits[i][j], backend))
    dxf = ar_row(_each(g_r, g_c, lambda i, j: M.mm_dx(
        dlogits[i][j], P(i, j, "head_w"), backend)), g_r, g_c)
    bstf = ar_col(_each(g_r, g_c, lambda i, j: M.ln_bwd_stats(
        x[i][j], stf[i][j], P(i, j, "lnf_g"), dxf[i][j], total_h=h)), g_r, g_c)
    dx = [[None] * g_c for _ in range(g_r)]
    for i in range(g_r):
        for j in range(g_c):
            d, dg_, db_ = M.ln_bwd_finish(
                x[i][j], stf[i][j], P(i, j, "lnf_g"), dxf[i][j], bstf[i][j], total_h=h)
            dx[i][j] = d
            setg(i, j, "lnf_g", dg_)
            setg(i, j, "lnf_b", db_)

    for l in reversed(range(cfg.layers)):
        pre, st1, xn, qkv, att, x1, st2, x1n, upre, u = cache[l]
        # mlp2 (transposed): bwd AR over COLUMN comm
        for i in range(g_r):
            for j in range(g_c):
                setg(i, j, f"b{l}.bmlp2", jnp.sum(dx[i][j], axis=0))
                setg(i, j, f"b{l}.wmlp2", M.mm_dw(u[i][j], dx[i][j], backend))
        dv = ar_col(_each(g_r, g_c, lambda i, j: M.mm_dx(
            dx[i][j], P(i, j, f"b{l}.wmlp2"), backend)), g_r, g_c)
        dupre = [[None] * g_c for _ in range(g_r)]
        for i in range(g_r):
            for j in range(g_c):
                zb = jnp.zeros((upre[i][j].shape[1],), upre[i][j].dtype)
                du_, db_ = M.bias_act_bwd(upre[i][j], zb, dv[i][j], "gelu")
                dupre[i][j] = du_
                setg(i, j, f"b{l}.bmlp1", db_)
                setg(i, j, f"b{l}.wmlp1", M.mm_dw(x1n[i][j], du_, backend))
        # mlp1 (non-transposed): bwd AR over ROW comm
        dx1n = ar_row(_each(g_r, g_c, lambda i, j: M.mm_dx(
            dupre[i][j], P(i, j, f"b{l}.wmlp1"), backend)), g_r, g_c)
        bst2 = ar_col(_each(g_r, g_c, lambda i, j: M.ln_bwd_stats(
            x1[i][j], st2[i][j], P(i, j, f"b{l}.ln2_g"), dx1n[i][j], total_h=h)), g_r, g_c)
        dx1 = [[None] * g_c for _ in range(g_r)]
        for i in range(g_r):
            for j in range(g_c):
                d, dg_, db_ = M.ln_bwd_finish(
                    x1[i][j], st2[i][j], P(i, j, f"b{l}.ln2_g"), dx1n[i][j], bst2[i][j], total_h=h)
                dx1[i][j] = d + dx[i][j]  # residual
                setg(i, j, f"b{l}.ln2_g", dg_)
                setg(i, j, f"b{l}.ln2_b", db_)
        # out-projection (transposed): bwd AR over COLUMN comm
        for i in range(g_r):
            for j in range(g_c):
                setg(i, j, f"b{l}.bproj", jnp.sum(dx1[i][j], axis=0))
                setg(i, j, f"b{l}.wproj", M.mm_dw(att[i][j], dx1[i][j], backend))
        datt = ar_col(_each(g_r, g_c, lambda i, j: M.mm_dx(
            dx1[i][j], P(i, j, f"b{l}.wproj"), backend)), g_r, g_c)
        dqkv = _each(g_r, g_c, lambda i, j: M.attn_bwd(
            qkv[i][j], datt[i][j], mb=mb, seq=s, heads_local=hl, head_dim=cfg.head_dim))
        for i in range(g_r):
            for j in range(g_c):
                setg(i, j, f"b{l}.bqkv", jnp.sum(dqkv[i][j], axis=0))
                setg(i, j, f"b{l}.wqkv", M.mm_dw(xn[i][j], dqkv[i][j], backend))
        # qkv (non-transposed): bwd AR over ROW comm
        dxn = ar_row(_each(g_r, g_c, lambda i, j: M.mm_dx(
            dqkv[i][j], P(i, j, f"b{l}.wqkv"), backend)), g_r, g_c)
        bst1 = ar_col(_each(g_r, g_c, lambda i, j: M.ln_bwd_stats(
            pre[i][j], st1[i][j], P(i, j, f"b{l}.ln1_g"), dxn[i][j], total_h=h)), g_r, g_c)
        for i in range(g_r):
            for j in range(g_c):
                d, dg_, db_ = M.ln_bwd_finish(
                    pre[i][j], st1[i][j], P(i, j, f"b{l}.ln1_g"), dxn[i][j], bst1[i][j], total_h=h)
                dx[i][j] = d + dx1[i][j]  # residual into block input
                setg(i, j, f"b{l}.ln1_g", dg_)
                setg(i, j, f"b{l}.ln1_b", db_)

    for i in range(g_r):
        for j in range(g_c):
            _, dwpos = M.embed_bwd(tokens, dx[i][j])
            setg(i, j, "wpos", dwpos)
            setg(i, j, "wemb", M.embed_bwd_table(tokens, dx[i][j], cfg.vocab))

    return loss, g
